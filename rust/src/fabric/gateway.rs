//! Client gateway: the pipelined submission API a transactor drives.
//!
//! [`Gateway::submit`] runs the *synchronous* front half of a transaction
//! — endorse across peers, check rw-set agreement, assemble the envelope,
//! pass admission control into the orderer's mempool — and returns a
//! non-blocking [`SubmitHandle`] carrying the endorse/admission result
//! immediately. The commit outcome resolves later through the handle
//! ([`SubmitHandle::wait`] / [`SubmitHandle::try_wait`]), so a client can
//! keep thousands of transactions in flight without a thread each.
//!
//! Handle lifecycle: `submit` registers the tx id with the channel's
//! [`CommitWaiter`] *before* the envelope reaches the orderer (a commit
//! can never race past its waiter), the demux routes the one matching
//! [`CommitEvent`](super::peer::CommitEvent) to the handle, and dropping
//! an unresolved handle deregisters it. One waiter — one
//! `Peer::subscribe` stream — exists per (gateway, channel) no matter how
//! many transactions are in flight; the old design gave every in-flight
//! tx its own subscription that scanned all commit events (O(N²) clones
//! under load).
//!
//! [`Gateway::submit_all`] is the open-loop batch driver (bounded
//! in-flight window, drains `Reject::PoolFull` backpressure by waiting
//! out the oldest in-flight tx), and [`Gateway::submit_and_wait`] remains
//! as a one-line closed-loop shim with the paper's 30 s timeout
//! semantics.

use std::collections::{HashMap, VecDeque};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use crate::ledger::block::ValidationCode;
use crate::ledger::envelope::SharedEnvelope;
use crate::ledger::tx::{Envelope, Proposal, TxId};
use crate::mempool::Reject;
use crate::telemetry::{self, Stage};

use super::orderer::OrderingService;
use super::peer::Peer;
use super::waiter::{CommitWaiter, WaiterEvent};

/// Outcome of a submitted transaction.
#[derive(Clone, Debug, PartialEq)]
pub enum CommitOutcome {
    /// Committed with this validation code after `latency`.
    Committed { code: ValidationCode, latency: Duration },
    /// All/enough endorsements failed (chaincode or policy rejection).
    EndorsementFailed { reason: String, latency: Duration },
    /// The mempool refused the envelope at admission (backpressure: pool
    /// full, rate cap, replay, stale read-set, …). The transaction was
    /// never ordered.
    Rejected { reject: Reject, latency: Duration },
    /// No commit event within the timeout. Besides genuine pipeline
    /// stalls, this is how an admitted tx that the mempool later shed as
    /// stale (`stale_dropped` — guaranteed `MvccConflict`, never ordered)
    /// surfaces; re-endorse and resubmit.
    TimedOut,
}

impl CommitOutcome {
    pub fn is_valid(&self) -> bool {
        matches!(self, CommitOutcome::Committed { code: ValidationCode::Valid, .. })
    }

    /// Was this shed by ingress admission control (not a failure of the
    /// transaction itself)?
    pub fn is_rejected(&self) -> bool {
        matches!(self, CommitOutcome::Rejected { .. })
    }
}

enum HandleState {
    /// Outcome known already: resolved at submit time (endorsement failure,
    /// admission reject) or drained from the demux.
    Resolved(CommitOutcome),
    /// Awaiting a [`WaiterEvent`] through the channel's demux — the commit
    /// event, or a relay-drop rejection pushed by the orderer's relay
    /// (events come stamped with their arrival time, so latency is
    /// measured to the outcome, not to whenever the handle gets drained).
    /// The handle keeps the waiter (and its demux thread) alive until it
    /// resolves.
    Pending { rx: mpsc::Receiver<WaiterEvent>, waiter: Arc<CommitWaiter> },
}

/// A submitted transaction whose commit outcome resolves asynchronously.
///
/// Returned by [`Gateway::submit`] with the endorse/admission verdict
/// already decided: [`SubmitHandle::outcome`] is `Some` immediately for
/// endorsement failures and mempool rejects, and the commit result arrives
/// later via [`wait`](SubmitHandle::wait) / [`try_wait`](SubmitHandle::try_wait).
/// Dropping a still-pending handle deregisters its waiter.
pub struct SubmitHandle {
    tx_id: TxId,
    started: Instant,
    timeout: Duration,
    state: HandleState,
}

impl SubmitHandle {
    /// An already-decided handle. `pub(crate)` so the remote client library
    /// can surface submit-time verdicts with the same API.
    pub(crate) fn resolved(
        tx_id: TxId,
        started: Instant,
        timeout: Duration,
        out: CommitOutcome,
    ) -> Self {
        SubmitHandle { tx_id, started, timeout, state: HandleState::Resolved(out) }
    }

    /// A handle awaiting a [`WaiterEvent`] through `waiter`'s table.
    /// `pub(crate)` so the remote client library can hand out real
    /// `SubmitHandle`s whose events are fed by its connection reader.
    pub(crate) fn pending(
        tx_id: TxId,
        started: Instant,
        timeout: Duration,
        rx: mpsc::Receiver<WaiterEvent>,
        waiter: Arc<CommitWaiter>,
    ) -> Self {
        SubmitHandle { tx_id, started, timeout, state: HandleState::Pending { rx, waiter } }
    }

    pub fn tx_id(&self) -> TxId {
        self.tx_id
    }

    /// Time since `submit` was called.
    pub fn elapsed(&self) -> Duration {
        self.started.elapsed()
    }

    /// Still awaiting its commit event?
    pub fn is_pending(&self) -> bool {
        matches!(self.state, HandleState::Pending { .. })
    }

    /// The outcome resolved so far (submit-time verdicts are available
    /// immediately; commit outcomes once a `wait`/`try_wait` drained them).
    pub fn outcome(&self) -> Option<&CommitOutcome> {
        match &self.state {
            HandleState::Resolved(out) => Some(out),
            HandleState::Pending { .. } => None,
        }
    }

    /// Non-blocking poll: `Some` once the outcome is known.
    pub fn try_wait(&mut self) -> Option<CommitOutcome> {
        let res = match &self.state {
            HandleState::Resolved(out) => return Some(out.clone()),
            HandleState::Pending { rx, .. } => rx.try_recv(),
        };
        match res {
            Ok(ev) => Some(self.resolve(ev)),
            Err(mpsc::TryRecvError::Empty) => None,
            Err(mpsc::TryRecvError::Disconnected) => Some(self.resolve_dead()),
        }
    }

    /// Block up to `timeout` (from now) for the outcome. Returns
    /// [`CommitOutcome::TimedOut`] without giving up the waiter slot: a
    /// late commit can still be drained by a later `wait`/`try_wait`.
    pub fn wait_timeout(&mut self, timeout: Duration) -> CommitOutcome {
        let res = match &self.state {
            HandleState::Resolved(out) => return out.clone(),
            HandleState::Pending { rx, .. } => rx.recv_timeout(timeout),
        };
        match res {
            Ok(ev) => self.resolve(ev),
            Err(mpsc::RecvTimeoutError::Timeout) => CommitOutcome::TimedOut,
            Err(mpsc::RecvTimeoutError::Disconnected) => self.resolve_dead(),
        }
    }

    /// Block for the outcome with the submitting gateway's timeout counted
    /// from submission (the old `submit_and_wait` semantics).
    pub fn wait(mut self) -> CommitOutcome {
        let remaining = self.timeout.saturating_sub(self.started.elapsed());
        self.wait_timeout(remaining)
    }

    fn resolve(&mut self, ev: WaiterEvent) -> CommitOutcome {
        let out = match ev {
            WaiterEvent::Committed(ev, at) => CommitOutcome::Committed {
                code: ev.code,
                latency: at.saturating_duration_since(self.started),
            },
            // The relay dropped the forwarded envelope before ordering:
            // the transaction is dead, surface it as the same explicit
            // backpressure an admission reject would be.
            WaiterEvent::Dropped(reject, at) => CommitOutcome::Rejected {
                reject,
                latency: at.saturating_duration_since(self.started),
            },
        };
        self.state = HandleState::Resolved(out.clone());
        out
    }

    /// The demux is gone (its channel or gateway was torn down); nothing
    /// can arrive any more.
    fn resolve_dead(&mut self) -> CommitOutcome {
        self.state = HandleState::Resolved(CommitOutcome::TimedOut);
        CommitOutcome::TimedOut
    }
}

impl Drop for SubmitHandle {
    fn drop(&mut self) {
        if let HandleState::Pending { waiter, .. } = &self.state {
            waiter.deregister(&self.tx_id);
        }
    }
}

/// Gateway bound to a set of endorsing peers and the ordering service.
pub struct Gateway {
    pub endorsers: Vec<Arc<Peer>>,
    pub orderer: Arc<OrderingService>,
    /// Transaction timeout (paper: 30 s).
    pub timeout: Duration,
    /// The shard ingress this gateway submits through. `None` routes
    /// straight to each envelope's home pool (an idealized router);
    /// `Some(channel)` models a client attached to one shard: envelopes
    /// for other channels enter that shard's pool and ride the
    /// cross-shard relay home, paying a simnet link latency per hop
    /// (requires the orderer to run a relay — without one, submissions
    /// fall back to direct routing).
    pub ingress: Option<String>,
    /// One commit-event demux per channel this gateway has submitted on.
    waiters: Mutex<HashMap<String, Arc<CommitWaiter>>>,
}

impl Gateway {
    pub fn new(endorsers: Vec<Arc<Peer>>, orderer: Arc<OrderingService>) -> Gateway {
        Gateway {
            endorsers,
            orderer,
            timeout: Duration::from_secs(30),
            ingress: None,
            waiters: Mutex::new(HashMap::new()),
        }
    }

    /// Endorse in parallel across peers; require every collected rw-set to
    /// agree (Fabric's determinism requirement — identical model hashes
    /// evaluate identically, paper §3.3). The result is the canonical
    /// [`SharedEnvelope`], encoded exactly once here at proposal time —
    /// every later hop (admission, relay, batch splice, a `Submit` frame
    /// over a socket) reuses the same buffer.
    pub fn endorse(&self, proposal: &Proposal) -> Result<SharedEnvelope, String> {
        let results: Vec<_> = std::thread::scope(|s| {
            let handles: Vec<_> = self
                .endorsers
                .iter()
                .map(|p| {
                    let p = Arc::clone(p);
                    let prop = proposal.clone();
                    s.spawn(move || p.endorse(&prop))
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("endorser panicked")).collect()
        });
        let mut rw = None;
        let mut endorsements = Vec::new();
        let mut errors = Vec::new();
        for r in results {
            match r {
                Ok((rwset, e, _payload)) => {
                    if let Some(prev) = &rw {
                        if *prev != rwset {
                            return Err("endorsement divergence: rw-sets disagree".into());
                        }
                    } else {
                        rw = Some(rwset);
                    }
                    endorsements.push(e);
                }
                Err(e) => errors.push(e),
            }
        }
        match rw {
            Some(rw_set) => {
                Ok(Envelope { proposal: proposal.clone(), rw_set, endorsements }.into())
            }
            None => Err(format!("all endorsements failed: {}", errors.join("; "))),
        }
    }

    /// The channel's commit demux, created (with its single subscription)
    /// on first use. When the orderer runs a cross-shard relay, the demux
    /// also registers as a relay drop sink: a transaction forwarded out of
    /// an ingress pool and then dropped (home pool full, shutdown, …)
    /// resolves its handle as `Rejected` instead of leaking an
    /// eternally-pending waiter slot until the client's timeout.
    pub(crate) fn waiter(&self, channel: &str) -> Result<Arc<CommitWaiter>, String> {
        let mut waiters = self.waiters.lock().unwrap();
        if let Some(w) = waiters.get(channel) {
            return Ok(Arc::clone(w));
        }
        let sub = self
            .endorsers
            .first()
            .ok_or_else(|| "gateway has no endorsers".to_string())?
            .subscribe(channel)?;
        let w = Arc::new(CommitWaiter::start(channel, sub));
        if let Some(relay) = self.orderer.relay() {
            // Registered weakly: the sink must not keep the waiter (and
            // its demux thread) alive after the gateway and all handles
            // are gone — the relay prunes dead entries on its own.
            relay.on_drop(Arc::downgrade(&w));
        }
        waiters.insert(channel.to_string(), Arc::clone(&w));
        Ok(w)
    }

    /// The synchronous front half of a submission — demux lookup plus the
    /// expensive endorsement (real PJRT evaluations on every peer). `Err`
    /// is an already-resolved failure handle.
    fn endorse_for(
        &self,
        proposal: &Proposal,
        started: Instant,
    ) -> Result<(SharedEnvelope, Arc<CommitWaiter>), SubmitHandle> {
        let fail = |reason: String| {
            let out = CommitOutcome::EndorsementFailed { reason, latency: started.elapsed() };
            SubmitHandle::resolved(proposal.tx_id(), started, self.timeout, out)
        };
        let waiter = match self.waiter(&proposal.channel) {
            Ok(w) => w,
            Err(reason) => return Err(fail(reason)),
        };
        match self.endorse(proposal) {
            Ok(envelope) => Ok((envelope, waiter)),
            Err(reason) => Err(fail(reason)),
        }
    }

    /// The back half: register with the demux, then pass admission control.
    /// Reusable with the same envelope (no re-endorsement) when admission
    /// bounces it with backpressure. Also the entry point for the node
    /// server's remotely-submitted envelopes (already canonical bytes).
    pub(crate) fn order_endorsed(
        &self,
        envelope: SharedEnvelope,
        waiter: &Arc<CommitWaiter>,
        started: Instant,
    ) -> SubmitHandle {
        let timeout = self.timeout;
        let tx_id = envelope.tx_id();
        // Register before ordering so the commit event cannot be missed.
        let Some(rx) = waiter.register(tx_id) else {
            // Already in flight through this gateway.
            let out =
                CommitOutcome::Rejected { reject: Reject::Duplicate, latency: started.elapsed() };
            return SubmitHandle::resolved(tx_id, started, timeout, out);
        };
        // Lifecycle epoch: the tx is demux-registered and headed for
        // admission control.
        telemetry::global().stamp(&tx_id, Stage::Submit);
        if let Err(reject) = self.orderer.submit_from(self.ingress.as_deref(), envelope) {
            waiter.deregister(&tx_id);
            // Admission rejects are fully accounted by mempool counters;
            // free the trace slot without recording a lifecycle.
            telemetry::global().discard(&tx_id);
            let out = CommitOutcome::Rejected { reject, latency: started.elapsed() };
            return SubmitHandle::resolved(tx_id, started, timeout, out);
        }
        let waiter = Arc::clone(waiter);
        SubmitHandle { tx_id, started, timeout, state: HandleState::Pending { rx, waiter } }
    }

    /// Non-blocking submission: endorse, register with the channel demux,
    /// and pass admission control. The returned handle already carries the
    /// endorse/admission verdict; the commit outcome resolves through it.
    pub fn submit(&self, proposal: &Proposal) -> SubmitHandle {
        let started = Instant::now();
        match self.endorse_for(proposal, started) {
            Ok((envelope, waiter)) => self.order_endorsed(envelope, &waiter, started),
            Err(handle) => handle,
        }
    }

    /// Open-loop batch driver: submit every proposal with at most
    /// `max_in_flight` transactions awaiting commit at once. `PoolFull`
    /// backpressure is absorbed by draining the oldest in-flight tx and
    /// retrying; only when nothing is left to drain does the rejection
    /// surface in the outcomes. Outcomes are positionally aligned with
    /// `proposals`.
    pub fn submit_all(&self, proposals: &[Proposal], max_in_flight: usize) -> Vec<CommitOutcome> {
        /// Resolve the oldest in-flight tx; false when the window is empty.
        fn drain_oldest(
            window: &mut VecDeque<(usize, SubmitHandle)>,
            outcomes: &mut [Option<CommitOutcome>],
        ) -> bool {
            match window.pop_front() {
                Some((j, h)) => {
                    outcomes[j] = Some(h.wait());
                    true
                }
                None => false,
            }
        }
        let max = max_in_flight.max(1);
        let mut outcomes: Vec<Option<CommitOutcome>> = (0..proposals.len()).map(|_| None).collect();
        let mut window: VecDeque<(usize, SubmitHandle)> = VecDeque::new();
        for (i, proposal) in proposals.iter().enumerate() {
            while window.len() >= max {
                drain_oldest(&mut window, &mut outcomes);
            }
            let started = Instant::now();
            let handle = match self.endorse_for(proposal, started) {
                Ok((envelope, waiter)) => {
                    // Endorsement is the expensive half; PoolFull retries
                    // re-order the *same* envelope after waiting out the
                    // oldest in-flight tx. The clone per attempt is a
                    // refcount bump on the canonical buffer.
                    let mut h = self.order_endorsed(envelope.clone(), &waiter, started);
                    while matches!(
                        h.outcome(),
                        Some(CommitOutcome::Rejected { reject: Reject::PoolFull, .. })
                    ) && drain_oldest(&mut window, &mut outcomes)
                    {
                        h = self.order_endorsed(envelope.clone(), &waiter, started);
                    }
                    h
                }
                Err(h) => h,
            };
            if handle.is_pending() {
                window.push_back((i, handle));
            } else {
                outcomes[i] = Some(handle.wait());
            }
        }
        while drain_oldest(&mut window, &mut outcomes) {}
        outcomes.into_iter().map(|o| o.expect("every proposal resolved")).collect()
    }

    /// Closed-loop shim over [`Gateway::submit`]: one transaction,
    /// blocking until commit or the gateway timeout.
    pub fn submit_and_wait(&self, proposal: &Proposal) -> CommitOutcome {
        self.submit(proposal).wait()
    }

    /// Transactions currently awaiting their commit event through this
    /// gateway (all channels).
    pub fn in_flight(&self) -> usize {
        self.waiters.lock().unwrap().values().map(|w| w.pending()).sum()
    }

    /// Highest per-channel in-flight depth this gateway has reached.
    pub fn in_flight_high_water(&self) -> usize {
        self.waiters.lock().unwrap().values().map(|w| w.high_water()).max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::msp::{CertificateAuthority, MemberId};
    use crate::fabric::chaincode::{Chaincode, TxContext};
    use crate::fabric::endorsement::EndorsementPolicy;
    use crate::fabric::orderer::OrdererConfig;
    use crate::util::prng::Prng;

    struct PutOrFail;
    impl Chaincode for PutOrFail {
        fn name(&self) -> &str {
            "kv"
        }
        fn invoke(
            &self,
            ctx: &mut TxContext<'_>,
            f: &str,
            args: &[String],
        ) -> Result<Vec<u8>, String> {
            if f == "Fail" {
                return Err("policy rejected".into());
            }
            if f == "ReadPut" {
                // Read-modify-write: records an MVCC dependency on the key.
                let _ = ctx.get(&args[0]);
            }
            ctx.put(&args[0], b"v".to_vec());
            Ok(vec![])
        }
    }

    fn gateway_with(
        n: usize,
        cfg: OrdererConfig,
        mempool: Option<Arc<crate::mempool::MempoolRegistry>>,
    ) -> (Vec<Arc<Peer>>, Gateway) {
        let ca = CertificateAuthority::new();
        let mut rng = Prng::new(2);
        let peers: Vec<Arc<Peer>> = (0..n)
            .map(|i| {
                let cred = ca.enroll(MemberId::new(format!("org{i}.peer")), &mut rng);
                Peer::new(cred, ca.clone())
            })
            .collect();
        let members: Vec<MemberId> = peers.iter().map(|p| p.member.clone()).collect();
        for p in &peers {
            p.join_channel("ch", EndorsementPolicy::MajorityOf(members.clone()));
            p.install_chaincode("ch", Arc::new(PutOrFail)).unwrap();
        }
        let orderer = match mempool {
            Some(m) => OrderingService::start_with_mempool(cfg, peers.clone(), 7, m),
            None => OrderingService::start(cfg, peers.clone(), 7),
        };
        (peers.clone(), Gateway::new(peers, orderer))
    }

    fn gateway(n: usize) -> (Vec<Arc<Peer>>, Gateway) {
        gateway_with(
            n,
            OrdererConfig { batch_timeout: Duration::from_millis(10), ..Default::default() },
            None,
        )
    }

    fn prop(f: &str, key: &str, nonce: u64) -> Proposal {
        Proposal {
            channel: "ch".into(),
            chaincode: "kv".into(),
            function: f.into(),
            args: vec![key.into()],
            creator: MemberId::new("client"),
            nonce,
        }
    }

    #[test]
    fn submit_and_wait_commits() {
        let (peers, gw) = gateway(3);
        let out = gw.submit_and_wait(&prop("Put", "a", 1));
        assert!(out.is_valid(), "{out:?}");
        assert_eq!(peers[1].channel("ch").unwrap().query("a"), Some(b"v".to_vec()));
    }

    #[test]
    fn endorsement_failure_reported() {
        let (_peers, gw) = gateway(3);
        let out = gw.submit_and_wait(&prop("Fail", "a", 2));
        assert!(matches!(out, CommitOutcome::EndorsementFailed { .. }), "{out:?}");
    }

    #[test]
    fn backpressure_surfaces_as_rejected() {
        use crate::mempool::{MempoolConfig, MempoolRegistry, Reject};
        let ca = CertificateAuthority::new();
        let mut rng = Prng::new(5);
        let peers: Vec<Arc<Peer>> = (0..2)
            .map(|i| {
                let cred = ca.enroll(MemberId::new(format!("org{i}.peer")), &mut rng);
                Peer::new(cred, ca.clone())
            })
            .collect();
        let members: Vec<MemberId> = peers.iter().map(|p| p.member.clone()).collect();
        for p in &peers {
            p.join_channel("ch", EndorsementPolicy::MajorityOf(members.clone()));
            p.install_chaincode("ch", Arc::new(PutOrFail)).unwrap();
        }
        // One tx per ~17 minutes: the second submission hits the rate cap.
        let mempool = MempoolRegistry::new(MempoolConfig {
            rate_limit: Some(0.001),
            rate_burst: 1.0,
            ..Default::default()
        });
        let orderer = OrderingService::start_with_mempool(
            OrdererConfig { batch_timeout: Duration::from_millis(10), ..Default::default() },
            peers.clone(),
            7,
            mempool,
        );
        let gw = Gateway::new(peers, orderer);
        assert!(gw.submit_and_wait(&prop("Put", "a", 1)).is_valid());
        let out = gw.submit_and_wait(&prop("Put", "b", 2));
        assert!(
            matches!(out, CommitOutcome::Rejected { reject: Reject::RateLimited, .. }),
            "{out:?}"
        );
        assert!(out.is_rejected());
        assert_eq!(gw.orderer.mempool().snapshot().rate_limited, 1);
    }

    /// Orderer throttled hard enough that submissions pile up in flight.
    fn throttled() -> (Vec<Arc<Peer>>, Gateway) {
        gateway_with(
            2,
            OrdererConfig {
                batch_size: 4,
                batch_timeout: Duration::from_millis(5),
                min_block_interval: Duration::from_millis(40),
                tick: Duration::from_millis(1),
                ..Default::default()
            },
            None,
        )
    }

    #[test]
    fn concurrent_handles_resolve_distinct_outcomes() {
        let (peers, gw) = throttled();
        let n = 12;
        let handles: Vec<SubmitHandle> =
            (0..n).map(|i| gw.submit(&prop("Put", &format!("k{i}"), i))).collect();
        // Everything is in flight at once over ONE commit-event
        // subscription: the demux is O(channels), not O(transactions).
        assert_eq!(peers[0].channel("ch").unwrap().listener_count(), 1);
        assert!(gw.in_flight_high_water() >= 4, "{}", gw.in_flight_high_water());
        for (i, h) in handles.into_iter().enumerate() {
            let out = h.wait();
            assert!(out.is_valid(), "tx {i}: {out:?}");
        }
        assert_eq!(gw.in_flight(), 0);
        for i in 0..n {
            assert_eq!(
                peers[1].channel("ch").unwrap().query(&format!("k{i}")),
                Some(b"v".to_vec()),
                "tx {i} not committed"
            );
        }
    }

    #[test]
    fn wait_timeout_returns_then_late_commit_resolves() {
        // One lone tx only commits on the 300 ms batch-timeout cut.
        let (_peers, gw) = gateway_with(
            2,
            OrdererConfig {
                batch_size: 100,
                batch_timeout: Duration::from_millis(300),
                ..Default::default()
            },
            None,
        );
        let mut h = gw.submit(&prop("Put", "late", 1));
        assert!(h.is_pending());
        assert_eq!(h.try_wait(), None);
        // A short wait times out without losing the waiter slot...
        assert_eq!(h.wait_timeout(Duration::from_millis(30)), CommitOutcome::TimedOut);
        assert!(h.is_pending());
        // ...so the late commit is still delivered to the same handle.
        let out = h.wait_timeout(Duration::from_secs(10));
        assert!(out.is_valid(), "{out:?}");
        assert_eq!(h.outcome(), Some(&out));
    }

    #[test]
    fn dropped_handle_deregisters_its_waiter() {
        let (_peers, gw) = throttled();
        let h = gw.submit(&prop("Put", "gone", 1));
        assert!(h.is_pending());
        assert_eq!(gw.in_flight(), 1);
        drop(h);
        assert_eq!(gw.in_flight(), 0);
        // The eventual commit event for the abandoned tx routes nowhere;
        // a subsequent submission on the same demux still resolves.
        let out = gw.submit(&prop("Put", "next", 2)).wait();
        assert!(out.is_valid(), "{out:?}");
    }

    #[test]
    fn duplicate_in_flight_submission_rejected_at_gateway() {
        let (_peers, gw) = throttled();
        let h = gw.submit(&prop("Put", "dup", 1));
        assert!(h.is_pending());
        let second = gw.submit(&prop("Put", "dup", 1));
        assert!(
            matches!(
                second.outcome(),
                Some(CommitOutcome::Rejected { reject: Reject::Duplicate, .. })
            ),
            "{:?}",
            second.outcome()
        );
        assert!(h.wait().is_valid());
    }

    #[test]
    fn submit_all_honors_max_in_flight_under_pool_full() {
        use crate::mempool::{MempoolConfig, MempoolRegistry};
        // Tiny pool (2 per lane) + throttled consensus: the open-loop
        // window must run into PoolFull backpressure and absorb it by
        // draining in-flight txs rather than shedding its own load.
        let mempool =
            MempoolRegistry::new(MempoolConfig { lane_capacity: 2, ..Default::default() });
        let (_peers, gw) = gateway_with(
            2,
            OrdererConfig {
                batch_size: 2,
                batch_timeout: Duration::from_millis(5),
                min_block_interval: Duration::from_millis(50),
                tick: Duration::from_millis(1),
                ..Default::default()
            },
            Some(mempool),
        );
        let proposals: Vec<Proposal> =
            (0..16).map(|i| prop("Put", &format!("w{i}"), i)).collect();
        let outcomes = gw.submit_all(&proposals, 4);
        assert_eq!(outcomes.len(), 16);
        for (i, out) in outcomes.iter().enumerate() {
            assert!(out.is_valid(), "tx {i}: {out:?}");
        }
        assert!(gw.in_flight_high_water() <= 4, "{}", gw.in_flight_high_water());
        let stats = gw.orderer.mempool().snapshot();
        assert!(stats.pool_full > 0, "expected PoolFull backpressure, got {stats:?}");
        assert_eq!(stats.txs_ordered, 16);
    }

    /// Admission-side MVCC hinting surfaces through the pipelined API as
    /// an immediately-resolved `CommitOutcome::Rejected`: a transaction
    /// endorsed on a lagging replica (its read versions already overtaken
    /// on the replica backing the mempool's state view) is refused before
    /// ordering, not invalidated after consensus.
    #[test]
    fn stale_read_set_resolves_as_rejected_handle() {
        use crate::mempool::Reject;
        let ca = CertificateAuthority::new();
        let mut rng = Prng::new(9);
        let fresh = {
            let cred = ca.enroll(MemberId::new("org0.peer"), &mut rng);
            Peer::new(cred, ca.clone())
        };
        let laggard = {
            let cred = ca.enroll(MemberId::new("org1.peer"), &mut rng);
            Peer::new(cred, ca.clone())
        };
        let policy =
            EndorsementPolicy::AnyOf(1, vec![fresh.member.clone(), laggard.member.clone()]);
        for p in [&fresh, &laggard] {
            p.join_channel("ch", policy.clone());
            p.install_chaincode("ch", Arc::new(PutOrFail)).unwrap();
        }
        // The orderer wires its mempool's staleness oracle to `fresh`.
        let orderer = OrderingService::start(
            OrdererConfig { batch_timeout: Duration::from_millis(10), ..Default::default() },
            vec![Arc::clone(&fresh)],
            3,
        );
        // `fresh` commits a write to the contended key; `laggard` misses it.
        let prop_ahead = prop("Put", "ctr", 1);
        let (rw, e, _) = fresh.endorse(&prop_ahead).unwrap();
        let ahead = Envelope { proposal: prop_ahead, rw_set: rw, endorsements: vec![e] };
        fresh.commit_batch("ch", vec![ahead]).unwrap();
        // Endorsing on the laggard observes ctr as absent — provably stale
        // against the view replica, so admission rejects at submit time.
        let gw = Gateway::new(vec![laggard], orderer);
        let handle = gw.submit(&prop("ReadPut", "ctr", 2));
        assert!(!handle.is_pending(), "stale verdict must resolve at submit");
        let out = handle.wait();
        assert!(
            matches!(out, CommitOutcome::Rejected { reject: Reject::StaleReadSet, .. }),
            "{out:?}"
        );
        assert!(out.is_rejected());
        let stats = gw.orderer.mempool().snapshot();
        assert_eq!(stats.stale_read_set, 1);
        assert_eq!(stats.stale_shed(), 1);
    }

    /// A gateway bound to a foreign shard's ingress: its submissions ride
    /// the cross-shard relay home.
    fn relay_gateway(cfg: OrdererConfig) -> (Vec<Arc<Peer>>, Gateway) {
        let ca = CertificateAuthority::new();
        let mut rng = Prng::new(31);
        let peers: Vec<Arc<Peer>> = (0..2)
            .map(|i| {
                let cred = ca.enroll(MemberId::new(format!("org{i}.peer")), &mut rng);
                Peer::new(cred, ca.clone())
            })
            .collect();
        let members: Vec<MemberId> = peers.iter().map(|p| p.member.clone()).collect();
        for p in &peers {
            p.join_channel("ch", EndorsementPolicy::MajorityOf(members.clone()));
            p.install_chaincode("ch", Arc::new(PutOrFail)).unwrap();
        }
        let orderer = OrderingService::start(cfg, peers.clone(), 31);
        let mut gw = Gateway::new(peers.clone(), orderer);
        gw.ingress = Some("edge".into());
        (peers, gw)
    }

    fn relay_orderer_cfg() -> OrdererConfig {
        OrdererConfig {
            batch_timeout: Duration::from_millis(10),
            tick: Duration::from_millis(1),
            relay: Some(crate::mempool::RelayConfig {
                base_latency: Duration::from_millis(4),
                latency_spread: Duration::from_millis(4),
                jitter: Duration::from_millis(1),
                seed: 8,
            }),
            ..OrdererConfig::default()
        }
    }

    #[test]
    fn forwarded_submission_resolves_through_handle() {
        let (peers, gw) = relay_gateway(relay_orderer_cfg());
        let out = gw.submit(&prop("Put", "far", 1)).wait();
        assert!(out.is_valid(), "{out:?}");
        assert_eq!(peers[0].channel("ch").unwrap().query("far"), Some(b"v".to_vec()));
        let stats = gw.orderer.mempool().snapshot();
        assert_eq!(stats.forwarded, 1, "rode the relay, not the direct router");
        assert_eq!(gw.orderer.relay().unwrap().snapshot().delivered, 1);
    }

    /// Regression for the Subscription/CommitWaiter leak: a transaction
    /// forwarded out of an ingress pool and then dropped by the relay
    /// (home pool full) must resolve its originating handle promptly as
    /// `Rejected` — not pend until the 30 s gateway timeout with a leaked
    /// waiter slot.
    #[test]
    fn relay_dropped_forward_resolves_handle() {
        use crate::mempool::{MempoolConfig, MempoolRegistry};
        // Home lane capacity 1 and no consensus bandwidth: whatever is in
        // the home pool stays there, so the forwarded tx finds it full.
        let mempool =
            MempoolRegistry::new(MempoolConfig { lane_capacity: 1, ..Default::default() });
        let ca = CertificateAuthority::new();
        let mut rng = Prng::new(37);
        let peers: Vec<Arc<Peer>> = (0..2)
            .map(|i| {
                let cred = ca.enroll(MemberId::new(format!("org{i}.peer")), &mut rng);
                Peer::new(cred, ca.clone())
            })
            .collect();
        let members: Vec<MemberId> = peers.iter().map(|p| p.member.clone()).collect();
        for p in &peers {
            p.join_channel("ch", EndorsementPolicy::MajorityOf(members.clone()));
            p.install_chaincode("ch", Arc::new(PutOrFail)).unwrap();
        }
        let cfg = OrdererConfig {
            batch_size: 1000,
            batch_timeout: Duration::from_secs(60),
            min_block_interval: Duration::from_secs(60),
            tick: Duration::from_millis(1),
            relay: relay_orderer_cfg().relay,
            ..OrdererConfig::default()
        };
        let orderer = OrderingService::start_with_mempool(cfg, peers.clone(), 37, mempool);
        // Occupy the home lane directly.
        let filler_rw = peers[0].endorse(&prop("Put", "filler", 1)).unwrap().0;
        let filler = crate::ledger::tx::Envelope {
            proposal: prop("Put", "filler", 1),
            rw_set: filler_rw,
            endorsements: Vec::new(),
        };
        orderer.submit(filler).unwrap();

        let mut gw = Gateway::new(peers.clone(), orderer);
        gw.ingress = Some("edge".into());
        gw.timeout = Duration::from_secs(30);
        let started = Instant::now();
        let h = gw.submit(&prop("Put", "doomed", 2));
        assert!(h.is_pending(), "forward accepted at ingress, outcome pends");
        let out = h.wait();
        assert!(
            matches!(out, CommitOutcome::Rejected { reject: Reject::PoolFull, .. }),
            "{out:?}"
        );
        assert!(
            started.elapsed() < Duration::from_secs(10),
            "resolved by the relay drop, not the gateway timeout"
        );
        // The waiter slot was released — no leak.
        assert_eq!(gw.in_flight(), 0);
        let stats = gw.orderer.mempool().snapshot();
        assert_eq!(stats.forwarded, 1);
        assert_eq!(stats.relay_dropped, 1);
    }

    #[test]
    fn timeout_when_orderer_unreachable() {
        let (peers, mut gw) = gateway(2);
        // Replace the orderer with one that delivers to nobody.
        gw.orderer = OrderingService::start(OrdererConfig::default(), Vec::new(), 8);
        gw.timeout = Duration::from_millis(150);
        let out = gw.submit_and_wait(&prop("Put", "a", 3));
        assert_eq!(out, CommitOutcome::TimedOut);
        assert_eq!(peers[0].channel("ch").unwrap().query("a"), None);
    }
}
