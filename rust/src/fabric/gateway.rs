//! Client gateway: the submit-and-wait flow a transactor runs — fan the
//! proposal out to endorsing peers, check rw-set agreement, assemble the
//! envelope, hand it to the orderer, and wait for the commit event
//! (with the paper's 30 s timeout semantics).

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::ledger::block::ValidationCode;
use crate::ledger::tx::{Envelope, Proposal};
use crate::mempool::Reject;

use super::orderer::OrderingService;
use super::peer::Peer;

/// Outcome of a submitted transaction.
#[derive(Clone, Debug, PartialEq)]
pub enum CommitOutcome {
    /// Committed with this validation code after `latency`.
    Committed { code: ValidationCode, latency: Duration },
    /// All/enough endorsements failed (chaincode or policy rejection).
    EndorsementFailed { reason: String, latency: Duration },
    /// The mempool refused the envelope at admission (backpressure: pool
    /// full, rate cap, replay, …). The transaction was never ordered.
    Rejected { reject: Reject, latency: Duration },
    /// No commit event within the timeout.
    TimedOut,
}

impl CommitOutcome {
    pub fn is_valid(&self) -> bool {
        matches!(self, CommitOutcome::Committed { code: ValidationCode::Valid, .. })
    }

    /// Was this shed by ingress admission control (not a failure of the
    /// transaction itself)?
    pub fn is_rejected(&self) -> bool {
        matches!(self, CommitOutcome::Rejected { .. })
    }
}

/// Gateway bound to a set of endorsing peers and the ordering service.
pub struct Gateway {
    pub endorsers: Vec<Arc<Peer>>,
    pub orderer: Arc<OrderingService>,
    /// Transaction timeout (paper: 30 s).
    pub timeout: Duration,
}

impl Gateway {
    pub fn new(endorsers: Vec<Arc<Peer>>, orderer: Arc<OrderingService>) -> Gateway {
        Gateway { endorsers, orderer, timeout: Duration::from_secs(30) }
    }

    /// Endorse in parallel across peers; require every collected rw-set to
    /// agree (Fabric's determinism requirement — identical model hashes
    /// evaluate identically, paper §3.3).
    pub fn endorse(&self, proposal: &Proposal) -> Result<Envelope, String> {
        let results: Vec<_> = std::thread::scope(|s| {
            let handles: Vec<_> = self
                .endorsers
                .iter()
                .map(|p| {
                    let p = Arc::clone(p);
                    let prop = proposal.clone();
                    s.spawn(move || p.endorse(&prop))
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("endorser panicked")).collect()
        });
        let mut rw = None;
        let mut endorsements = Vec::new();
        let mut errors = Vec::new();
        for r in results {
            match r {
                Ok((rwset, e, _payload)) => {
                    if let Some(prev) = &rw {
                        if *prev != rwset {
                            return Err("endorsement divergence: rw-sets disagree".into());
                        }
                    } else {
                        rw = Some(rwset);
                    }
                    endorsements.push(e);
                }
                Err(e) => errors.push(e),
            }
        }
        match rw {
            Some(rw_set) => Ok(Envelope { proposal: proposal.clone(), rw_set, endorsements }),
            None => Err(format!("all endorsements failed: {}", errors.join("; "))),
        }
    }

    /// Full transaction flow; `listener` must be subscribed on the target
    /// channel *before* calling (the gateway subscribes internally).
    pub fn submit_and_wait(&self, proposal: &Proposal) -> CommitOutcome {
        let started = Instant::now();
        let tx_id = proposal.tx_id();
        // Subscribe before ordering so the commit event cannot be missed.
        let rx = match self.endorsers[0].subscribe(&proposal.channel) {
            Ok(rx) => rx,
            Err(e) => {
                return CommitOutcome::EndorsementFailed {
                    reason: e,
                    latency: started.elapsed(),
                }
            }
        };
        let envelope = match self.endorse(proposal) {
            Ok(env) => env,
            Err(reason) => {
                return CommitOutcome::EndorsementFailed { reason, latency: started.elapsed() }
            }
        };
        if let Err(reject) = self.orderer.submit(envelope) {
            return CommitOutcome::Rejected { reject, latency: started.elapsed() };
        }
        loop {
            let remaining = self.timeout.saturating_sub(started.elapsed());
            if remaining.is_zero() {
                return CommitOutcome::TimedOut;
            }
            match rx.recv_timeout(remaining) {
                Ok(ev) if ev.tx_id == tx_id => {
                    return CommitOutcome::Committed { code: ev.code, latency: started.elapsed() }
                }
                Ok(_) => continue,
                Err(_) => return CommitOutcome::TimedOut,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::msp::{CertificateAuthority, MemberId};
    use crate::fabric::chaincode::{Chaincode, TxContext};
    use crate::fabric::endorsement::EndorsementPolicy;
    use crate::fabric::orderer::OrdererConfig;
    use crate::util::prng::Prng;

    struct PutOrFail;
    impl Chaincode for PutOrFail {
        fn name(&self) -> &str {
            "kv"
        }
        fn invoke(
            &self,
            ctx: &mut TxContext<'_>,
            f: &str,
            args: &[String],
        ) -> Result<Vec<u8>, String> {
            if f == "Fail" {
                return Err("policy rejected".into());
            }
            ctx.put(&args[0], b"v".to_vec());
            Ok(vec![])
        }
    }

    fn gateway(n: usize) -> (Vec<Arc<Peer>>, Gateway) {
        let ca = CertificateAuthority::new();
        let mut rng = Prng::new(2);
        let peers: Vec<Arc<Peer>> = (0..n)
            .map(|i| {
                let cred = ca.enroll(MemberId::new(format!("org{i}.peer")), &mut rng);
                Peer::new(cred, ca.clone())
            })
            .collect();
        let members: Vec<MemberId> = peers.iter().map(|p| p.member.clone()).collect();
        for p in &peers {
            p.join_channel("ch", EndorsementPolicy::MajorityOf(members.clone()));
            p.install_chaincode("ch", Arc::new(PutOrFail)).unwrap();
        }
        let orderer = OrderingService::start(
            OrdererConfig { batch_timeout: Duration::from_millis(10), ..Default::default() },
            peers.clone(),
            7,
        );
        (peers.clone(), Gateway::new(peers, orderer))
    }

    fn prop(f: &str, key: &str, nonce: u64) -> Proposal {
        Proposal {
            channel: "ch".into(),
            chaincode: "kv".into(),
            function: f.into(),
            args: vec![key.into()],
            creator: MemberId::new("client"),
            nonce,
        }
    }

    #[test]
    fn submit_and_wait_commits() {
        let (peers, gw) = gateway(3);
        let out = gw.submit_and_wait(&prop("Put", "a", 1));
        assert!(out.is_valid(), "{out:?}");
        assert_eq!(peers[1].channel("ch").unwrap().query("a"), Some(b"v".to_vec()));
    }

    #[test]
    fn endorsement_failure_reported() {
        let (_peers, gw) = gateway(3);
        let out = gw.submit_and_wait(&prop("Fail", "a", 2));
        assert!(matches!(out, CommitOutcome::EndorsementFailed { .. }), "{out:?}");
    }

    #[test]
    fn backpressure_surfaces_as_rejected() {
        use crate::mempool::{MempoolConfig, MempoolRegistry, Reject};
        let ca = CertificateAuthority::new();
        let mut rng = Prng::new(5);
        let peers: Vec<Arc<Peer>> = (0..2)
            .map(|i| {
                let cred = ca.enroll(MemberId::new(format!("org{i}.peer")), &mut rng);
                Peer::new(cred, ca.clone())
            })
            .collect();
        let members: Vec<MemberId> = peers.iter().map(|p| p.member.clone()).collect();
        for p in &peers {
            p.join_channel("ch", EndorsementPolicy::MajorityOf(members.clone()));
            p.install_chaincode("ch", Arc::new(PutOrFail)).unwrap();
        }
        // One tx per ~17 minutes: the second submission hits the rate cap.
        let mempool = MempoolRegistry::new(MempoolConfig {
            rate_limit: Some(0.001),
            rate_burst: 1.0,
            ..Default::default()
        });
        let orderer = OrderingService::start_with_mempool(
            OrdererConfig { batch_timeout: Duration::from_millis(10), ..Default::default() },
            peers.clone(),
            7,
            mempool,
        );
        let gw = Gateway::new(peers, orderer);
        assert!(gw.submit_and_wait(&prop("Put", "a", 1)).is_valid());
        let out = gw.submit_and_wait(&prop("Put", "b", 2));
        assert!(
            matches!(out, CommitOutcome::Rejected { reject: Reject::RateLimited, .. }),
            "{out:?}"
        );
        assert!(out.is_rejected());
        assert_eq!(gw.orderer.mempool().snapshot().rate_limited, 1);
    }

    #[test]
    fn timeout_when_orderer_unreachable() {
        let (peers, mut gw) = gateway(2);
        // Replace the orderer with one that delivers to nobody.
        gw.orderer = OrderingService::start(OrdererConfig::default(), Vec::new(), 8);
        gw.timeout = Duration::from_millis(150);
        let out = gw.submit_and_wait(&prop("Put", "a", 3));
        assert_eq!(out, CommitOutcome::TimedOut);
        assert_eq!(peers[0].channel("ch").unwrap().query("a"), None);
    }
}
