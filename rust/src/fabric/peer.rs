//! Peers: endorsement simulation, staged block validation + commit, ledger
//! queries, and commit-event subscriptions.
//!
//! Each peer keeps its own chain + world state per joined channel (as in
//! Fabric); the ordering service delivers identical block payloads to every
//! peer, and determinism of the validator keeps replicas in agreement.
//!
//! # The two-stage commit pipeline
//!
//! [`Peer::commit_batch_with`] validates a block in two stages:
//!
//! 1. **Parallel pre-validation** (no chain/state locks): endorsement
//!    policy + signature verification for every transaction, fanned out
//!    over the [`BlockValidator`]'s worker pool and answered from its
//!    cross-peer verdict cache when another replica already validated the
//!    same block. This is the O(txs × endorsements) crypto that used to
//!    serialize on one core under the state lock.
//! 2. **Serial MVCC + apply** (under the chain/state/dedup locks):
//!    duplicate-txid check, read-version check against current state, and
//!    in-order application of valid write sets. Only this stage takes the
//!    state *write* lock, and it does no crypto — endorsement simulation
//!    and admission-side staleness probes (both read-lock users) are never
//!    blocked behind signature verification.
//!
//! The staging is outcome-invariant: validation codes are computed in the
//! same priority order as the old single-loop validator (duplicate →
//! policy → MVCC → apply), so serial and parallel validators produce
//! byte-identical blocks.
//!
//! [`PeerChannel`] also implements [`StateView`], exposing its world
//! state's read-version oracle to the mempool for admission-time MVCC
//! hinting (a transaction whose read-set is already stale can never
//! commit `Valid`; versions only move forward).

use std::collections::{HashMap, HashSet};
use std::sync::{mpsc, Arc, Mutex, RwLock, Weak};
use std::time::Instant;

use crate::crypto::msp::{CertificateAuthority, Credential, MemberId};
use crate::crypto::Digest;
use crate::ledger::block::{Block, ValidationCode};
use crate::ledger::chain::Chain;
use crate::ledger::envelope::SharedEnvelope;
use crate::ledger::snapshot::{self, Snapshot};
use crate::ledger::state::{StateView, Version, WorldState};
use crate::ledger::store::{LedgerConfig, LedgerStore};
use crate::ledger::tx::{endorsement_payload, Endorsement, Proposal, RwSet, TxId};
use crate::telemetry::{self, Stage};

use super::chaincode::{Chaincode, TxContext};
use super::endorsement::EndorsementPolicy;
use super::validator::BlockValidator;

/// Notification sent to subscribers when a transaction commits. The
/// channel name is interned (`Arc<str>`, one allocation per block), so the
/// per-listener clone fan-out in `commit_batch` bumps a refcount instead
/// of allocating a fresh `String` per event per listener.
#[derive(Clone, Debug)]
pub struct CommitEvent {
    pub channel: Arc<str>,
    pub tx_id: TxId,
    pub block: u64,
    pub code: ValidationCode,
}

/// A registered commit-event listener. `alive` mirrors the liveness of the
/// matching [`Subscription`]: once the subscriber drops its end, the entry
/// is pruned eagerly (on the subscription's own drop and on every
/// `subscribe`) instead of lingering until a send fails mid-commit.
struct Listener {
    tx: mpsc::Sender<CommitEvent>,
    alive: Weak<()>,
}

/// A live commit-event stream on one channel, returned by
/// [`Peer::subscribe`]. Derefs to the underlying [`mpsc::Receiver`], so
/// `recv` / `recv_timeout` / `try_recv` work directly. Dropping the
/// subscription deregisters the listener immediately.
pub struct Subscription {
    rx: mpsc::Receiver<CommitEvent>,
    token: Arc<()>,
    channel: Weak<PeerChannel>,
}

impl std::ops::Deref for Subscription {
    type Target = mpsc::Receiver<CommitEvent>;

    fn deref(&self) -> &Self::Target {
        &self.rx
    }
}

impl Drop for Subscription {
    fn drop(&mut self) {
        if let Some(ch) = self.channel.upgrade() {
            // `token` is still alive while this body runs, so remove our
            // own entry by identity, plus any other dead ones.
            let me = Arc::downgrade(&self.token);
            ch.listeners
                .lock()
                .unwrap()
                .retain(|l| l.alive.strong_count() > 0 && !Weak::ptr_eq(&l.alive, &me));
        }
    }
}

/// Per-channel replica state on a peer.
///
/// Lock layout mirrors the pipeline: `state` is a `RwLock` whose read half
/// serves endorsement simulation, queries, and staleness probes
/// concurrently; the write half belongs to the serial apply stage of
/// [`Peer::commit_batch_with`] alone.
pub struct PeerChannel {
    pub name: String,
    pub chain: Mutex<Chain>,
    pub state: RwLock<WorldState>,
    chaincodes: RwLock<HashMap<String, Arc<dyn Chaincode>>>,
    policy: RwLock<EndorsementPolicy>,
    committed_ids: Mutex<HashSet<TxId>>,
    listeners: Mutex<Vec<Listener>>,
    /// Durable block log for this replica, if [`Peer::attach_store`] ran.
    /// `None` keeps the channel purely in-memory (the historical behavior).
    store: Mutex<Option<Arc<LedgerStore>>>,
}

impl PeerChannel {
    fn new(name: &str, policy: EndorsementPolicy) -> Self {
        PeerChannel {
            name: name.to_string(),
            chain: Mutex::new(Chain::new()),
            state: RwLock::new(WorldState::new()),
            chaincodes: RwLock::new(HashMap::new()),
            policy: RwLock::new(policy),
            committed_ids: Mutex::new(HashSet::new()),
            listeners: Mutex::new(Vec::new()),
            store: Mutex::new(None),
        }
    }

    pub fn policy(&self) -> EndorsementPolicy {
        self.policy.read().unwrap().clone()
    }

    /// Upgrade the channel's endorsement policy (e.g. new committee).
    pub fn set_policy(&self, policy: EndorsementPolicy) {
        *self.policy.write().unwrap() = policy;
    }

    /// Read a committed value (query path; no transaction).
    pub fn query(&self, key: &str) -> Option<Vec<u8>> {
        self.state.read().unwrap().get_value(key).map(|v| v.to_vec())
    }

    pub fn scan(&self, prefix: &str) -> Vec<(String, Vec<u8>)> {
        self.state
            .read()
            .unwrap()
            .scan_prefix(prefix)
            .into_iter()
            .map(|(k, v)| (k.to_string(), v.to_vec()))
            .collect()
    }

    pub fn height(&self) -> u64 {
        self.chain.lock().unwrap().height()
    }

    /// Merkle root over the replica's current world state (the same root a
    /// [`Snapshot`] of this state would carry). Two replicas agree on
    /// every key, value, and version iff their roots match — the
    /// recovery acceptance check.
    pub fn state_root(&self) -> Digest {
        snapshot::state_root(&self.state.read().unwrap().entries())
    }

    /// The attached durable store, if any.
    pub fn store(&self) -> Option<Arc<LedgerStore>> {
        self.store.lock().unwrap().clone()
    }

    /// Live commit-event listeners (dead entries are pruned first). The
    /// gateway demux keeps this O(channels), not O(in-flight transactions):
    /// tests assert on it.
    pub fn listener_count(&self) -> usize {
        let mut listeners = self.listeners.lock().unwrap();
        listeners.retain(|l| l.alive.strong_count() > 0);
        listeners.len()
    }
}

/// The mempool's staleness oracle: current read versions straight off the
/// replica's world state, through the read lock only.
impl StateView for PeerChannel {
    fn read_version(&self, key: &str) -> Option<Version> {
        self.state.read().unwrap().read_version(key)
    }

    fn seq(&self) -> u64 {
        self.state.read().unwrap().seq()
    }
}

/// What [`Peer::attach_store`] did to bring a channel replica back: where
/// recovery started, how much it replayed, and the resulting tip.
#[derive(Clone, Debug)]
pub struct RecoveryReport {
    /// Height the restored snapshot covered (0 = no snapshot, full replay).
    pub snapshot_height: u64,
    /// Blocks replayed from the log through the validator path.
    pub replayed_blocks: u64,
    /// Torn-tail bytes truncated off the log.
    pub truncated_bytes: u64,
    /// A snapshot file existed but was unusable; recovery fell back to
    /// replaying the whole log.
    pub snapshot_fallback: bool,
    /// Chain height after recovery.
    pub height: u64,
    /// State Merkle root after recovery.
    pub state_root: Digest,
}

/// A network peer (holds ledgers, endorses, validates).
pub struct Peer {
    pub member: MemberId,
    cred: Credential,
    ca: CertificateAuthority,
    channels: RwLock<HashMap<String, Arc<PeerChannel>>>,
    /// Fallback validator for direct [`Peer::commit_batch`] calls (serial,
    /// private cache). The ordering service passes its own shared one via
    /// [`Peer::commit_batch_with`] so replicas pool their verdicts.
    validator: Arc<BlockValidator>,
}

impl Peer {
    pub fn new(cred: Credential, ca: CertificateAuthority) -> Arc<Peer> {
        Arc::new(Peer {
            member: cred.member.clone(),
            cred,
            ca,
            channels: RwLock::new(HashMap::new()),
            validator: Arc::new(BlockValidator::serial()),
        })
    }

    /// Join a channel with the given endorsement policy.
    pub fn join_channel(&self, name: &str, policy: EndorsementPolicy) -> Arc<PeerChannel> {
        let ch = Arc::new(PeerChannel::new(name, policy));
        self.channels.write().unwrap().insert(name.to_string(), Arc::clone(&ch));
        ch
    }

    pub fn channel(&self, name: &str) -> Option<Arc<PeerChannel>> {
        self.channels.read().unwrap().get(name).cloned()
    }

    /// Names of every channel this peer has joined (sorted).
    pub fn channel_names(&self) -> Vec<String> {
        let mut names: Vec<String> = self.channels.read().unwrap().keys().cloned().collect();
        names.sort();
        names
    }

    /// Deploy a chaincode to a joined channel.
    pub fn install_chaincode(&self, channel: &str, cc: Arc<dyn Chaincode>) -> Result<(), String> {
        let ch = self.channel(channel).ok_or_else(|| format!("not joined: {channel}"))?;
        ch.chaincodes.write().unwrap().insert(cc.name().to_string(), cc);
        Ok(())
    }

    /// Endorsement: simulate the proposal and sign the resulting rw-set.
    /// This is where the model-evaluation cost lands (paper §3.4.5-3.4.6).
    pub fn endorse(&self, proposal: &Proposal) -> Result<(RwSet, Endorsement, Vec<u8>), String> {
        let ch = self
            .channel(&proposal.channel)
            .ok_or_else(|| format!("{}: not joined {}", self.member, proposal.channel))?;
        let cc = ch
            .chaincodes
            .read()
            .unwrap()
            .get(&proposal.chaincode)
            .cloned()
            .ok_or_else(|| format!("chaincode {} not installed", proposal.chaincode))?;
        let mut ctx = TxContext::new(&ch.state);
        let payload = cc.invoke(&mut ctx, &proposal.function, &proposal.args)?;
        let rw_set = ctx.into_rw_set();
        let sig = self.cred.sign(&endorsement_payload(&proposal.tx_id(), &rw_set.digest()));
        Ok((rw_set, Endorsement { endorser: self.member.clone(), signature: sig }, payload))
    }

    /// Validate + commit an ordered batch as the next block on `channel`
    /// using this peer's private serial validator. Kept for direct callers
    /// and tests; the pipelined path is [`Peer::commit_batch_with`].
    pub fn commit_batch<E: Into<SharedEnvelope>>(
        &self,
        channel: &str,
        envelopes: Vec<E>,
    ) -> Result<Block, String> {
        let validator = Arc::clone(&self.validator);
        self.commit_batch_with(&validator, channel, envelopes)
    }

    /// Validate + commit an ordered batch through the two-stage pipeline
    /// (module docs): parallel policy pre-validation on `validator`, then
    /// the serial MVCC-check + apply stage under the state write lock.
    ///
    /// Deterministic: validation codes are assigned in the same priority
    /// order as the historical serial loop (duplicate-txid, endorsement
    /// policy, MVCC read-version, apply), whatever the worker count.
    pub fn commit_batch_with<E: Into<SharedEnvelope>>(
        &self,
        validator: &BlockValidator,
        channel: &str,
        envelopes: Vec<E>,
    ) -> Result<Block, String> {
        let ch = self.channel(channel).ok_or_else(|| format!("not joined: {channel}"))?;
        let policy = ch.policy();

        // Stage 1 — lock-free fan-out (and cross-peer verdict reuse).
        // Envelopes arriving from the orderer are already shared buffers;
        // `into` is a move. Workers hold refcounts, never payload clones.
        let envelopes: Vec<SharedEnvelope> =
            envelopes.into_iter().map(Into::into).collect();
        let policy_ok = validator.prevalidate(&policy, &self.ca, &envelopes);

        // Stage 2 — serial MVCC + apply under the block-commit locks.
        let mut chain = ch.chain.lock().unwrap();
        let mut state = ch.state.write().unwrap();
        let mut committed_ids = ch.committed_ids.lock().unwrap();
        // Timed from lock acquisition so `apply_nanos` is the serial
        // stage's own work, not contention queueing.
        let t_apply = Instant::now();
        let number = chain.height();
        let mut block = Block::new(number, chain.tip_hash(), envelopes);
        let channel_name: Arc<str> = Arc::from(channel);
        let mut events = Vec::with_capacity(block.txs.len());
        for (i, env) in block.txs.iter().enumerate() {
            let tx_id = env.tx_id();
            let code = if committed_ids.contains(&tx_id) {
                ValidationCode::DuplicateTxId
            } else if !policy_ok[i] {
                ValidationCode::EndorsementPolicyFailure
            } else if !state.mvcc_valid(env.rw_set()) {
                ValidationCode::MvccConflict
            } else {
                state.apply(env.rw_set(), Version { block: number, tx: i as u32 });
                committed_ids.insert(tx_id);
                ValidationCode::Valid
            };
            block.validation.push(code);
            // First replica to decide the code stamps the apply stage
            // (first-write-wins keeps later replicas from moving it).
            telemetry::global().stamp(&tx_id, Stage::Apply);
            events.push(CommitEvent {
                channel: Arc::clone(&channel_name),
                tx_id,
                block: number,
                code,
            });
        }
        chain.append(block.clone()).map_err(|e| e.to_string())?;
        // Persist while still under the commit locks so log order always
        // equals chain order; the snapshot cut is captured here too, but
        // its (fsync-heavy) write happens after the locks drop.
        let store = ch.store.lock().unwrap().clone();
        let mut pending_snapshot = None;
        if let Some(store) = &store {
            store.append(&block).map_err(|e| format!("ledger append: {e}"))?;
            if store.should_snapshot(chain.height()) {
                pending_snapshot = Some(Snapshot::capture(
                    chain.height(),
                    chain.tip_hash(),
                    &state,
                    committed_ids.iter().cloned(),
                ));
            }
        }
        drop((chain, state, committed_ids));
        if let (Some(store), Some(snap)) = (&store, pending_snapshot) {
            if let Err(e) = store.write_snapshot(&snap) {
                eprintln!("{}: snapshot write failed: {e}", self.member);
            }
        }
        validator.note_apply(t_apply.elapsed().as_nanos() as u64, &block.validation);
        let mut listeners = ch.listeners.lock().unwrap();
        listeners.retain(|l| {
            l.alive.strong_count() > 0 && events.iter().all(|e| l.tx.send(e.clone()).is_ok())
        });
        Ok(block)
    }

    /// Attach a durable [`LedgerStore`] to a joined channel, recovering
    /// whatever a previous process durably persisted.
    ///
    /// Recovery order (module docs in `ledger`): load the latest valid
    /// snapshot, restore world state / dedup set / chain base from it,
    /// then replay the block-log suffix through the regular validation
    /// path — recomputed validation codes must match the logged ones
    /// block-for-block, and the hash chain is re-verified by
    /// `Chain::append` as each block lands. Torn log tails were already
    /// truncated by `LedgerStore::open`.
    ///
    /// Must run on an *empty* channel (fresh `join_channel`), before the
    /// replica starts committing; calling it again once attached is a
    /// no-op that reports the current tip. Replay checks endorsements
    /// against the channel's *current* policy, so restore the same policy
    /// the blocks were committed under.
    pub fn attach_store(
        &self,
        channel: &str,
        cfg: &LedgerConfig,
    ) -> Result<RecoveryReport, String> {
        let ch = self.channel(channel).ok_or_else(|| format!("not joined: {channel}"))?;
        if ch.store.lock().unwrap().is_some() {
            return Ok(RecoveryReport {
                snapshot_height: 0,
                replayed_blocks: 0,
                truncated_bytes: 0,
                snapshot_fallback: false,
                height: ch.height(),
                state_root: ch.state_root(),
            });
        }
        if ch.height() != 0 || ch.state.read().unwrap().seq() != 0 {
            return Err(format!("attach_store: channel {channel} is not empty"));
        }
        let dir = cfg.dir.join(self.member.0.as_str()).join(channel);
        let (store, recovery) = LedgerStore::open(
            &dir,
            channel,
            self.member.0.as_str(),
            cfg.durability,
            cfg.snapshot_every,
        )?;
        let mut snapshot_height = 0;
        if let Some(snap) = &recovery.snapshot {
            snapshot_height = snap.height;
            *ch.state.write().unwrap() =
                WorldState::from_entries(snap.entries.iter().cloned(), snap.seq);
            *ch.chain.lock().unwrap() = Chain::with_base(snap.height, snap.tip_hash);
            *ch.committed_ids.lock().unwrap() = snap.committed_ids.iter().cloned().collect();
        }
        for block in &recovery.replay {
            self.replay_block(&ch, block)?;
        }
        let report = RecoveryReport {
            snapshot_height,
            replayed_blocks: recovery.replay.len() as u64,
            truncated_bytes: recovery.truncated_bytes,
            snapshot_fallback: recovery.snapshot_fallback,
            height: ch.height(),
            state_root: ch.state_root(),
        };
        // Attach only after replay so replayed blocks aren't re-appended
        // to the very log they came from.
        *ch.store.lock().unwrap() = Some(store);
        Ok(report)
    }

    /// Re-commit one logged block during recovery: same two-stage path as
    /// [`Peer::commit_batch_with`] (policy prevalidation, then serial
    /// duplicate → policy → MVCC → apply), but the verdicts are *checked*
    /// against the logged codes instead of being the source of truth, and
    /// no commit events or telemetry stamps fire.
    fn replay_block(&self, ch: &PeerChannel, block: &Block) -> Result<(), String> {
        let policy = ch.policy();
        let policy_ok = self.validator.prevalidate(&policy, &self.ca, &block.txs);
        let mut chain = ch.chain.lock().unwrap();
        let mut state = ch.state.write().unwrap();
        let mut committed_ids = ch.committed_ids.lock().unwrap();
        let number = block.header.number;
        if number != chain.height() {
            return Err(format!(
                "replay out of order: block {number} at height {}",
                chain.height()
            ));
        }
        let mut recomputed = Vec::with_capacity(block.txs.len());
        for (i, env) in block.txs.iter().enumerate() {
            let tx_id = env.tx_id();
            let code = if committed_ids.contains(&tx_id) {
                ValidationCode::DuplicateTxId
            } else if !policy_ok[i] {
                ValidationCode::EndorsementPolicyFailure
            } else if !state.mvcc_valid(env.rw_set()) {
                ValidationCode::MvccConflict
            } else {
                state.apply(env.rw_set(), Version { block: number, tx: i as u32 });
                committed_ids.insert(tx_id);
                ValidationCode::Valid
            };
            recomputed.push(code);
        }
        if recomputed != block.validation {
            return Err(format!(
                "replay diverged at block {number}: logged {:?}, recomputed {recomputed:?}",
                block.validation
            ));
        }
        chain.append(block.clone()).map_err(|e| format!("replay block {number}: {e}"))?;
        Ok(())
    }

    /// Subscribe to commit events on a channel. Dead listeners left behind
    /// by dropped subscriptions are pruned before the new one registers.
    pub fn subscribe(&self, channel: &str) -> Result<Subscription, String> {
        let ch = self.channel(channel).ok_or_else(|| format!("not joined: {channel}"))?;
        let (tx, rx) = mpsc::channel();
        let token = Arc::new(());
        {
            let mut listeners = ch.listeners.lock().unwrap();
            listeners.retain(|l| l.alive.strong_count() > 0);
            listeners.push(Listener { tx, alive: Arc::downgrade(&token) });
        }
        Ok(Subscription { rx, token, channel: Arc::downgrade(&ch) })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ledger::tx::Envelope;
    use crate::util::prng::Prng;

    /// Toy chaincode: Put(k, v) writes, Get(k) reads, Fail errors.
    struct KvChaincode;

    impl Chaincode for KvChaincode {
        fn name(&self) -> &str {
            "kv"
        }

        fn invoke(
            &self,
            ctx: &mut TxContext<'_>,
            function: &str,
            args: &[String],
        ) -> Result<Vec<u8>, String> {
            match function {
                "Put" => {
                    ctx.put(&args[0], args[1].as_bytes().to_vec());
                    Ok(vec![])
                }
                "Incr" => {
                    let cur = ctx
                        .get(&args[0])
                        .and_then(|v| String::from_utf8(v).ok())
                        .and_then(|s| s.parse::<u64>().ok())
                        .unwrap_or(0);
                    ctx.put(&args[0], (cur + 1).to_string().into_bytes());
                    Ok(cur.to_string().into_bytes())
                }
                "Fail" => Err("chaincode rejected".into()),
                other => Err(format!("unknown function {other}")),
            }
        }
    }

    fn setup(n_peers: usize) -> (CertificateAuthority, Vec<Arc<Peer>>, EndorsementPolicy) {
        let ca = CertificateAuthority::new();
        let mut rng = Prng::new(1);
        let peers: Vec<Arc<Peer>> = (0..n_peers)
            .map(|i| {
                let cred = ca.enroll(MemberId::new(format!("org{i}.peer")), &mut rng);
                Peer::new(cred, ca.clone())
            })
            .collect();
        let members: Vec<MemberId> = peers.iter().map(|p| p.member.clone()).collect();
        let policy = EndorsementPolicy::MajorityOf(members);
        for p in &peers {
            p.join_channel("ch", policy.clone());
            p.install_chaincode("ch", Arc::new(KvChaincode)).unwrap();
        }
        (ca, peers, policy)
    }

    fn proposal(function: &str, args: &[&str], nonce: u64) -> Proposal {
        Proposal {
            channel: "ch".into(),
            chaincode: "kv".into(),
            function: function.into(),
            args: args.iter().map(|s| s.to_string()).collect(),
            creator: MemberId::new("client"),
            nonce,
        }
    }

    fn endorse_and_wrap(peers: &[Arc<Peer>], prop: &Proposal) -> Envelope {
        let mut endorsements = Vec::new();
        let mut rw = None;
        for p in peers {
            let (r, e, _) = p.endorse(prop).unwrap();
            if let Some(prev) = &rw {
                assert_eq!(*prev, r, "endorsement divergence");
            }
            rw = Some(r);
            endorsements.push(e);
        }
        Envelope { proposal: prop.clone(), rw_set: rw.unwrap(), endorsements }
    }

    #[test]
    fn full_endorse_order_validate_commit() {
        let (_ca, peers, _) = setup(3);
        let env = endorse_and_wrap(&peers, &proposal("Put", &["k", "v"], 1));
        for p in &peers {
            let block = p.commit_batch("ch", vec![env.clone()]).unwrap();
            assert_eq!(block.validation, vec![ValidationCode::Valid]);
            assert_eq!(p.channel("ch").unwrap().query("k"), Some(b"v".to_vec()));
        }
    }

    #[test]
    fn chaincode_error_rejects_endorsement() {
        let (_ca, peers, _) = setup(1);
        assert!(peers[0].endorse(&proposal("Fail", &[], 1)).is_err());
    }

    #[test]
    fn insufficient_endorsements_fail_policy() {
        let (_ca, peers, _) = setup(3); // majority = 2
        let prop = proposal("Put", &["k", "v"], 1);
        let (rw, e, _) = peers[0].endorse(&prop).unwrap();
        let env = Envelope { proposal: prop, rw_set: rw, endorsements: vec![e] };
        let block = peers[0].commit_batch("ch", vec![env]).unwrap();
        assert_eq!(block.validation, vec![ValidationCode::EndorsementPolicyFailure]);
        assert_eq!(peers[0].channel("ch").unwrap().query("k"), None);
    }

    #[test]
    fn mvcc_conflict_between_racing_txs() {
        let (_ca, peers, _) = setup(3);
        // Both txs read counter version None and write 1.
        let p1 = proposal("Incr", &["ctr"], 1);
        let p2 = proposal("Incr", &["ctr"], 2);
        let env1 = endorse_and_wrap(&peers, &p1);
        let env2 = endorse_and_wrap(&peers, &p2); // endorsed before env1 commits
        let block = peers[0].commit_batch("ch", vec![env1, env2]).unwrap();
        assert_eq!(
            block.validation,
            vec![ValidationCode::Valid, ValidationCode::MvccConflict]
        );
        assert_eq!(peers[0].channel("ch").unwrap().query("ctr"), Some(b"1".to_vec()));
    }

    #[test]
    fn duplicate_txid_rejected() {
        let (_ca, peers, _) = setup(3);
        let env = endorse_and_wrap(&peers, &proposal("Put", &["k", "v"], 1));
        peers[0].commit_batch("ch", vec![env.clone()]).unwrap();
        let block = peers[0].commit_batch("ch", vec![env]).unwrap();
        assert_eq!(block.validation, vec![ValidationCode::DuplicateTxId]);
    }

    #[test]
    fn replicas_stay_in_agreement() {
        let (_ca, peers, _) = setup(3);
        let mut envs = Vec::new();
        for i in 0..5 {
            envs.push(endorse_and_wrap(&peers, &proposal("Put", &[&format!("k{i}"), "v"], i)));
        }
        let blocks: Vec<Block> =
            peers.iter().map(|p| p.commit_batch("ch", envs.clone()).unwrap()).collect();
        for b in &blocks[1..] {
            assert_eq!(b.hash(), blocks[0].hash());
            assert_eq!(b.validation, blocks[0].validation);
        }
    }

    /// The acceptance determinism check: a mixed block (valid, policy
    /// failure, MVCC conflict, duplicate) must produce byte-identical
    /// results through the serial validator and a 4-worker parallel one.
    #[test]
    fn parallel_validation_matches_serial_exactly() {
        let (_ca, peers, _) = setup(4);
        let mut envs = Vec::new();
        // Two clean writes on distinct keys.
        envs.push(endorse_and_wrap(&peers, &proposal("Put", &["a", "v"], 1)));
        envs.push(endorse_and_wrap(&peers, &proposal("Put", &["b", "v"], 2)));
        // Policy failure: one endorsement where majority-of-4 needs 3.
        let prop = proposal("Put", &["c", "v"], 3);
        let (rw, e, _) = peers[0].endorse(&prop).unwrap();
        envs.push(Envelope { proposal: prop, rw_set: rw, endorsements: vec![e] });
        // MVCC conflict: both read ctr@None, second loses.
        envs.push(endorse_and_wrap(&peers, &proposal("Incr", &["ctr"], 4)));
        envs.push(endorse_and_wrap(&peers, &proposal("Incr", &["ctr"], 5)));
        // In-block duplicate of tx 1.
        envs.push(envs[0].clone());

        let serial = peers[0].commit_batch("ch", envs.clone()).unwrap();
        let parallel_v = BlockValidator::new(4);
        let parallel = peers[1].commit_batch_with(&parallel_v, "ch", envs).unwrap();
        assert_eq!(
            serial.validation,
            vec![
                ValidationCode::Valid,
                ValidationCode::Valid,
                ValidationCode::EndorsementPolicyFailure,
                ValidationCode::Valid,
                ValidationCode::MvccConflict,
                ValidationCode::DuplicateTxId,
            ]
        );
        assert_eq!(parallel.validation, serial.validation);
        assert_eq!(parallel.hash(), serial.hash());
        // Replica states agree too.
        assert_eq!(
            peers[0].channel("ch").unwrap().query("ctr"),
            peers[1].channel("ch").unwrap().query("ctr"),
        );
        let snap = parallel_v.snapshot();
        assert_eq!(snap.mvcc_conflicts, 1);
        assert_eq!(snap.policy_failures, 1);
        assert!(snap.prevalidate_nanos > 0);
    }

    /// Replicas committing the same block through one shared validator pay
    /// the signature crypto once; later peers hit the verdict cache.
    #[test]
    fn shared_validator_caches_across_peers() {
        let (_ca, peers, _) = setup(3);
        let envs: Vec<Envelope> = (0..6)
            .map(|i| endorse_and_wrap(&peers, &proposal("Put", &[&format!("k{i}"), "v"], i)))
            .collect();
        let shared = BlockValidator::new(2);
        let first = peers[0].commit_batch_with(&shared, "ch", envs.clone()).unwrap();
        let after_first = shared.snapshot();
        assert_eq!(after_first.cache_misses, 6);
        assert_eq!(after_first.cache_hits, 0);
        for p in &peers[1..] {
            let b = p.commit_batch_with(&shared, "ch", envs.clone()).unwrap();
            assert_eq!(b.validation, first.validation);
            assert_eq!(b.hash(), first.hash());
        }
        let snap = shared.snapshot();
        assert_eq!(snap.cache_misses, 6, "crypto ran once");
        assert_eq!(snap.cache_hits, 12, "two replicas served from cache");
        assert_eq!(snap.blocks, 3);
    }

    #[test]
    fn channel_state_view_reports_versions() {
        let (_ca, peers, _) = setup(1);
        let ch = peers[0].channel("ch").unwrap();
        assert_eq!(StateView::seq(ch.as_ref()), 0);
        assert_eq!(ch.read_version("k"), None);
        let prop = proposal("Put", &["k", "v"], 1);
        let env = endorse_and_wrap(&peers[..1], &prop);
        // Majority of 1 = 1, so the single endorsement commits.
        peers[0].commit_batch("ch", vec![env]).unwrap();
        assert_eq!(ch.read_version("k"), Some(Version { block: 0, tx: 0 }));
        assert_eq!(StateView::seq(ch.as_ref()), 1);
        assert!(ch.any_stale(&[("k".to_string(), None)]));
        assert!(!ch.any_stale(&[("k".to_string(), Some(Version { block: 0, tx: 0 }))]));
    }

    #[test]
    fn dropped_subscriptions_pruned_eagerly() {
        let (_ca, peers, _) = setup(1);
        let ch = peers[0].channel("ch").unwrap();
        let s1 = peers[0].subscribe("ch").unwrap();
        let s2 = peers[0].subscribe("ch").unwrap();
        assert_eq!(ch.listener_count(), 2);
        // Dropping a subscription removes its listener immediately — no
        // commit (and thus no failed send) required.
        drop(s2);
        assert_eq!(ch.listener_count(), 1);
        drop(s1);
        // A fresh subscribe prunes whatever is left before registering.
        let s3 = peers[0].subscribe("ch").unwrap();
        assert_eq!(ch.listener_count(), 1);
        // The survivor still receives events.
        let env = endorse_and_wrap(&peers, &proposal("Put", &["k", "v"], 1));
        peers[0].commit_batch("ch", vec![env]).unwrap();
        assert!(s3.try_recv().is_ok());
    }

    #[test]
    fn attach_store_persists_and_recovers_channel() {
        use crate::ledger::store::{DurabilityMode, LedgerConfig};
        use crate::util::tempdir::TempDir;

        let dir = TempDir::new("peer-store");
        let mut cfg = LedgerConfig::new(dir.path().to_path_buf());
        cfg.durability = DurabilityMode::Strict;
        cfg.snapshot_every = 4;

        let ca = CertificateAuthority::new();
        let mut rng = Prng::new(7);
        let cred = ca.enroll(MemberId::new("org0.peer"), &mut rng);
        let policy = EndorsementPolicy::MajorityOf(vec![cred.member.clone()]);

        let make_peer = || {
            let p = Peer::new(cred.clone(), ca.clone());
            p.join_channel("ch", policy.clone());
            p.install_chaincode("ch", Arc::new(KvChaincode)).unwrap();
            p
        };

        let peer = make_peer();
        let rep = peer.attach_store("ch", &cfg).unwrap();
        assert_eq!(rep.height, 0);
        let peers = vec![peer];
        for i in 0..5u64 {
            let env =
                endorse_and_wrap(&peers, &proposal("Put", &[&format!("k{i}"), "v"], i));
            peers[0].commit_batch("ch", vec![env]).unwrap();
        }
        // Commit one policy failure so replay must reproduce a non-Valid
        // code (exercises the code-comparison path).
        let prop = proposal("Put", &["reject", "v"], 99);
        let env = Envelope {
            proposal: prop.clone(),
            rw_set: RwSet { reads: vec![], writes: vec![("reject".into(), None)] },
            endorsements: vec![],
        };
        let b = peers[0].commit_batch("ch", vec![env]).unwrap();
        assert_eq!(b.validation, vec![ValidationCode::EndorsementPolicyFailure]);

        let ch = peers[0].channel("ch").unwrap();
        let (tip, height, root) =
            (ch.chain.lock().unwrap().tip_hash(), ch.height(), ch.state_root());
        assert_eq!(height, 6);
        drop(ch);
        drop(peers);

        // "Restart": fresh peer, same credential and CA, same directory.
        let revived = make_peer();
        let rep = revived.attach_store("ch", &cfg).unwrap();
        assert_eq!(rep.height, 6);
        assert_eq!(rep.snapshot_height, 4, "snapshot_every = 4, height reached 6");
        assert_eq!(rep.replayed_blocks, 2);
        assert_eq!(rep.state_root, root);
        let ch = revived.channel("ch").unwrap();
        assert_eq!(ch.chain.lock().unwrap().tip_hash(), tip);
        assert_eq!(ch.query("k3"), Some(b"v".to_vec()));
        assert_eq!(ch.query("reject"), None);
        // Idempotent second attach reports the same tip.
        let again = revived.attach_store("ch", &cfg).unwrap();
        assert_eq!(again.height, height);

        // The recovered replica keeps committing on top of the old chain.
        let revived_peers = vec![revived];
        let env = endorse_and_wrap(&revived_peers, &proposal("Put", &["after", "v"], 1000));
        let block = revived_peers[0].commit_batch("ch", vec![env]).unwrap();
        assert_eq!(block.header.number, 6);
        assert_eq!(block.header.prev_hash, tip);
    }

    #[test]
    fn attach_store_rejects_non_empty_channel() {
        use crate::ledger::store::LedgerConfig;
        use crate::util::tempdir::TempDir;

        let (_ca, peers, _) = setup(1);
        let env = endorse_and_wrap(&peers, &proposal("Put", &["k", "v"], 1));
        peers[0].commit_batch("ch", vec![env]).unwrap();
        let dir = TempDir::new("peer-nonempty");
        let err = peers[0]
            .attach_store("ch", &LedgerConfig::new(dir.path().to_path_buf()))
            .unwrap_err();
        assert!(err.contains("not empty"), "{err}");
    }

    #[test]
    fn commit_events_delivered() {
        let (_ca, peers, _) = setup(3);
        let rx = peers[0].subscribe("ch").unwrap();
        let env = endorse_and_wrap(&peers, &proposal("Put", &["k", "v"], 1));
        let tx_id = env.tx_id();
        peers[0].commit_batch("ch", vec![env]).unwrap();
        let ev = rx.try_recv().unwrap();
        assert_eq!(ev.tx_id, tx_id);
        assert_eq!(&*ev.channel, "ch");
        assert_eq!(ev.code, ValidationCode::Valid);
    }
}
