//! Endorsement policies: which (and how many) endorsing peers must sign a
//! transaction for it to validate at commit time.

use crate::crypto::msp::{CertificateAuthority, MemberId};
use crate::ledger::tx::{endorsement_payload, Endorsement, RwSet, TxId};

/// A channel's endorsement policy over its endorser set.
#[derive(Clone, Debug)]
pub enum EndorsementPolicy {
    /// At least `n` valid signatures from the member set.
    AnyOf(usize, Vec<MemberId>),
    /// Strict majority of the member set.
    MajorityOf(Vec<MemberId>),
}

impl EndorsementPolicy {
    pub fn members(&self) -> &[MemberId] {
        match self {
            EndorsementPolicy::AnyOf(_, m) | EndorsementPolicy::MajorityOf(m) => m,
        }
    }

    pub fn required(&self) -> usize {
        match self {
            EndorsementPolicy::AnyOf(n, _) => *n,
            EndorsementPolicy::MajorityOf(m) => m.len() / 2 + 1,
        }
    }

    /// Stable fingerprint over the policy's shape (variant, threshold,
    /// member set). Two policies with equal fingerprints accept exactly the
    /// same endorsement sets, so cached verification verdicts keyed by
    /// (envelope digest, fingerprint) are safe to share across peers and
    /// survive no-op policy reinstalls.
    pub fn fingerprint(&self) -> u64 {
        let required = (self.required() as u64).to_le_bytes();
        let mut parts: Vec<&[u8]> = vec![&required];
        for m in self.members() {
            parts.push(m.0.as_bytes());
        }
        let digest = crate::crypto::sha256_parts(&parts);
        u64::from_le_bytes(digest.0[..8].try_into().expect("digest >= 8 bytes"))
    }

    /// Validate endorsements over (tx, rw_set): signatures must verify, come
    /// from distinct policy members, and reach the required count.
    pub fn satisfied(
        &self,
        tx_id: &TxId,
        rw_set: &RwSet,
        endorsements: &[Endorsement],
        ca: &CertificateAuthority,
    ) -> bool {
        let payload = endorsement_payload(tx_id, &rw_set.digest());
        self.satisfied_prehashed(&payload, endorsements, ca)
    }

    /// [`EndorsementPolicy::satisfied`] with the endorsement payload
    /// (tx_id ‖ rw-digest) already computed — the hot path for callers
    /// holding cached envelope views, skipping the rw-set re-hash. One
    /// registry lock covers all signature checks for the envelope.
    pub fn satisfied_prehashed(
        &self,
        payload: &[u8],
        endorsements: &[Endorsement],
        ca: &CertificateAuthority,
    ) -> bool {
        let verifier = ca.batch_verifier();
        let mut seen: Vec<&MemberId> = Vec::new();
        let mut valid = 0usize;
        for e in endorsements {
            if seen.contains(&&e.endorser) {
                continue; // one vote per member
            }
            if !self.members().contains(&e.endorser) {
                continue; // not in the policy set
            }
            if verifier.verify(&e.endorser, payload, &e.signature) {
                seen.push(&e.endorser);
                valid += 1;
            }
        }
        valid >= self.required()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::msp::CertificateAuthority;
    use crate::crypto::sha256;
    use crate::util::prng::Prng;

    fn setup(n: usize) -> (CertificateAuthority, Vec<crate::crypto::msp::Credential>) {
        let ca = CertificateAuthority::new();
        let mut rng = Prng::new(1);
        let creds = (0..n)
            .map(|i| ca.enroll(MemberId::new(format!("org{i}.peer")), &mut rng))
            .collect();
        (ca, creds)
    }

    fn endorse_all(
        creds: &[crate::crypto::msp::Credential],
        tx: &TxId,
        rw: &RwSet,
    ) -> Vec<Endorsement> {
        let payload = endorsement_payload(tx, &rw.digest());
        creds
            .iter()
            .map(|c| Endorsement { endorser: c.member.clone(), signature: c.sign(&payload) })
            .collect()
    }

    #[test]
    fn majority_policy_counts() {
        let (ca, creds) = setup(4);
        let members: Vec<MemberId> = creds.iter().map(|c| c.member.clone()).collect();
        let policy = EndorsementPolicy::MajorityOf(members);
        assert_eq!(policy.required(), 3);
        let tx = sha256(b"tx");
        let rw = RwSet::default();
        let all = endorse_all(&creds, &tx, &rw);
        assert!(policy.satisfied(&tx, &rw, &all, &ca));
        assert!(policy.satisfied(&tx, &rw, &all[..3], &ca));
        assert!(!policy.satisfied(&tx, &rw, &all[..2], &ca));
    }

    #[test]
    fn duplicate_endorsements_count_once() {
        let (ca, creds) = setup(3);
        let members: Vec<MemberId> = creds.iter().map(|c| c.member.clone()).collect();
        let policy = EndorsementPolicy::AnyOf(2, members);
        let tx = sha256(b"tx");
        let rw = RwSet::default();
        let one = endorse_all(&creds[..1], &tx, &rw);
        let dup = vec![one[0].clone(), one[0].clone()];
        assert!(!policy.satisfied(&tx, &rw, &dup, &ca));
    }

    #[test]
    fn fingerprint_tracks_policy_shape() {
        let (_ca, creds) = setup(3);
        let members: Vec<MemberId> = creds.iter().map(|c| c.member.clone()).collect();
        let a = EndorsementPolicy::AnyOf(1, members.clone());
        let b = EndorsementPolicy::AnyOf(2, members.clone());
        let c = EndorsementPolicy::AnyOf(2, members[..2].to_vec());
        assert_eq!(a.fingerprint(), EndorsementPolicy::AnyOf(1, members.clone()).fingerprint());
        assert_ne!(a.fingerprint(), b.fingerprint(), "threshold changes the fingerprint");
        assert_ne!(b.fingerprint(), c.fingerprint(), "member set changes the fingerprint");
        // Same threshold + same members accept the same endorsement sets:
        // the fingerprints may legitimately coincide across variants.
        let maj = EndorsementPolicy::MajorityOf(members.clone());
        assert_eq!(maj.fingerprint(), EndorsementPolicy::AnyOf(2, members).fingerprint());
    }

    #[test]
    fn forged_or_foreign_signatures_rejected() {
        let (ca, creds) = setup(3);
        let members: Vec<MemberId> = creds.iter().map(|c| c.member.clone()).collect();
        let policy = EndorsementPolicy::AnyOf(1, members.clone());
        let tx = sha256(b"tx");
        let rw = RwSet::default();
        // Signature over a different rw-set digest.
        let other_rw = RwSet {
            reads: vec![],
            writes: vec![("k".into(), Some(b"evil".to_vec()))],
        };
        let stale = endorse_all(&creds, &tx, &other_rw);
        assert!(!policy.satisfied(&tx, &rw, &stale, &ca));
        // Member outside the policy.
        let mut rng = Prng::new(9);
        let outsider = ca.enroll(MemberId::new("mallory"), &mut rng);
        let payload = endorsement_payload(&tx, &rw.digest());
        let e = Endorsement { endorser: outsider.member.clone(), signature: outsider.sign(&payload) };
        assert!(!policy.satisfied(&tx, &rw, &[e], &ca));
    }
}
