//! The parallel half of the two-stage block-validation pipeline.
//!
//! Fabric's execute–order–validate model permits endorsement-policy and
//! signature verification to run *before* (and independently of) the serial
//! MVCC-check + apply step: the verdict for one transaction's signatures
//! depends on nothing but the envelope bytes and the channel policy. A
//! [`BlockValidator`] exploits that twice:
//!
//! - **Fan-out**: policy verification for a block's transactions is spread
//!   over a fixed [`ThreadPool`] (`workers > 1`), so a signature-heavy
//!   block uses every core instead of serializing O(txs × endorsements)
//!   HMAC checks on the committer thread.
//! - **Verdict cache**: every replica of a channel validates the *same*
//!   block payload. Verdicts are cached by (envelope digest, policy
//!   fingerprint), so N peers validating one block pay the crypto once
//!   and N−1 cache probes, instead of N× the crypto. The ordering
//!   service shares one validator across all its peers for precisely
//!   this reason. The signature-verification *membership registry* is
//!   not part of the key: peers sharing a validator must verify against
//!   the same `CertificateAuthority` — true of any channel's replicas,
//!   which agree on membership by construction — and a verdict is only
//!   as fresh as the registry (re-enrolling a member mid-flight has
//!   always invalidated outstanding signatures; cached verdicts age the
//!   same way).
//!
//! The serial stage (duplicate check, MVCC read-version check, state
//! apply) stays in [`crate::fabric::peer::Peer::commit_batch_with`] under
//! the chain/state locks; it reports its timing here so both stages export
//! through one [`ValidationSnapshot`].

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Instant;

use crate::crypto::msp::CertificateAuthority;
use crate::crypto::Digest;
use crate::ledger::block::ValidationCode;
use crate::ledger::envelope::SharedEnvelope;
use crate::ledger::tx::endorsement_payload;
use crate::telemetry::{self, Sample, Stage};
use crate::util::json::Json;
use crate::util::threadpool::ThreadPool;

use super::endorsement::EndorsementPolicy;

/// Cached verdicts kept before the table is cycled. Each entry is a
/// 40-byte key + bool; the cap bounds memory at a few MiB while holding
/// far more blocks than are ever in flight.
const CACHE_CAP: usize = 1 << 16;

/// Counters for both validation stages (atomics: the pre-validation stage
/// is inherently multi-threaded and several peers report concurrently).
#[derive(Debug, Default)]
struct ValidationStats {
    blocks: AtomicU64,
    txs: AtomicU64,
    prevalidate_nanos: AtomicU64,
    apply_nanos: AtomicU64,
    cache_hits: AtomicU64,
    cache_misses: AtomicU64,
    mvcc_conflicts: AtomicU64,
    policy_failures: AtomicU64,
    admit_txs: AtomicU64,
    admit_cache_hits: AtomicU64,
}

/// Point-in-time copy of a validator's counters. Times are cumulative
/// across every block any peer committed through this validator.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ValidationSnapshot {
    /// Blocks committed (one per peer per block — replicas count).
    pub blocks: u64,
    /// Transactions validated across those blocks.
    pub txs: u64,
    /// Total wall time in the parallel pre-validation stage.
    pub prevalidate_nanos: u64,
    /// Total wall time in the serial MVCC + apply stage.
    pub apply_nanos: u64,
    /// Pre-validation verdicts answered from the shared cache.
    pub cache_hits: u64,
    /// Verdicts that had to run the signature/policy crypto.
    pub cache_misses: u64,
    /// Transactions invalidated by a stale read version at commit.
    pub mvcc_conflicts: u64,
    /// Transactions invalidated by the endorsement policy.
    pub policy_failures: u64,
    /// Transactions crypto-verified on behalf of mempool admission
    /// (verdicts land in the same cache the commit path probes).
    pub admit_txs: u64,
    /// Admission verdicts answered from the shared cache.
    pub admit_cache_hits: u64,
}

impl ValidationSnapshot {
    pub fn prevalidate_s(&self) -> f64 {
        self.prevalidate_nanos as f64 / 1e9
    }

    pub fn apply_s(&self) -> f64 {
        self.apply_nanos as f64 / 1e9
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("blocks", self.blocks)
            .set("txs", self.txs)
            .set("prevalidate_s", self.prevalidate_s())
            .set("apply_s", self.apply_s())
            .set("cache_hits", self.cache_hits)
            .set("cache_misses", self.cache_misses)
            .set("mvcc_conflicts", self.mvcc_conflicts)
            .set("policy_failures", self.policy_failures)
            .set("admit_txs", self.admit_txs)
            .set("admit_cache_hits", self.admit_cache_hits)
    }
}

/// Shared pre-validation engine: a worker pool plus the cross-peer verdict
/// cache. One instance is typically owned by the ordering service and used
/// by every peer it delivers blocks to; `Peer::new` also carries a private
/// serial one so direct `commit_batch` calls keep working unchanged.
pub struct BlockValidator {
    workers: usize,
    pool: Option<ThreadPool>,
    /// (envelope digest, policy fingerprint) → policy satisfied?
    cache: Mutex<HashMap<(Digest, u64), bool>>,
    stats: ValidationStats,
}

impl BlockValidator {
    /// A validator fanning pre-validation out over `workers` threads
    /// (`workers <= 1` verifies inline on the caller's thread; the verdict
    /// cache is active either way).
    pub fn new(workers: usize) -> BlockValidator {
        let workers = workers.max(1);
        BlockValidator {
            workers,
            pool: if workers > 1 { Some(ThreadPool::new(workers)) } else { None },
            cache: Mutex::new(HashMap::new()),
            stats: ValidationStats::default(),
        }
    }

    /// Inline (single-threaded) validator — the default on a fresh peer.
    pub fn serial() -> BlockValidator {
        BlockValidator::new(1)
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    pub fn snapshot(&self) -> ValidationSnapshot {
        ValidationSnapshot {
            blocks: self.stats.blocks.load(Ordering::Relaxed),
            txs: self.stats.txs.load(Ordering::Relaxed),
            prevalidate_nanos: self.stats.prevalidate_nanos.load(Ordering::Relaxed),
            apply_nanos: self.stats.apply_nanos.load(Ordering::Relaxed),
            cache_hits: self.stats.cache_hits.load(Ordering::Relaxed),
            cache_misses: self.stats.cache_misses.load(Ordering::Relaxed),
            mvcc_conflicts: self.stats.mvcc_conflicts.load(Ordering::Relaxed),
            policy_failures: self.stats.policy_failures.load(Ordering::Relaxed),
            admit_txs: self.stats.admit_txs.load(Ordering::Relaxed),
            admit_cache_hits: self.stats.admit_cache_hits.load(Ordering::Relaxed),
        }
    }

    /// Stage 1: policy/signature verdict per envelope, in block order.
    /// Lock-free with respect to chain and state; envelopes are
    /// [`SharedEnvelope`]s, so worker threads hold refcounts (never
    /// payload clones) and every hash below is a cached-view read.
    pub fn prevalidate(
        &self,
        policy: &EndorsementPolicy,
        ca: &CertificateAuthority,
        envs: &[SharedEnvelope],
    ) -> Vec<bool> {
        let t0 = Instant::now();
        let (ok, verified) = self.verdicts(policy, ca, envs, false);
        // Cache misses mark the crypto replica: stamping only them (and
        // first-write-wins in the tracer) keeps replica re-validations
        // from moving the stage forward.
        for &i in &verified {
            telemetry::global().stamp(&envs[i].tx_id(), Stage::Prevalidate);
        }
        self.stats
            .prevalidate_nanos
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        ok
    }

    /// Crypto verdicts on behalf of mempool admission: same worker
    /// fan-out, same (envelope digest, policy fingerprint) cache — so a
    /// transaction verified once at admission is a pure cache hit when
    /// its block later prevalidates, and vice versa. Does not stamp the
    /// `Prevalidate` lifecycle stage or touch the commit-path counters;
    /// admission work is tallied separately (`admit_txs`,
    /// `admit_cache_hits`).
    pub fn admission_verify(
        &self,
        policy: &EndorsementPolicy,
        ca: &CertificateAuthority,
        envs: &[SharedEnvelope],
    ) -> Vec<bool> {
        let (ok, _) = self.verdicts(policy, ca, envs, true);
        ok
    }

    /// Shared verdict core: probe the cache, fan the misses out over the
    /// worker pool, insert the fresh verdicts. Returns the per-envelope
    /// verdicts plus the indices that actually ran crypto.
    fn verdicts(
        &self,
        policy: &EndorsementPolicy,
        ca: &CertificateAuthority,
        envs: &[SharedEnvelope],
        admission: bool,
    ) -> (Vec<bool>, Vec<usize>) {
        let fp = policy.fingerprint();
        let n = envs.len();
        let mut ok = vec![false; n];
        let keys: Vec<Digest> = envs.iter().map(|e| e.digest()).collect();
        let mut misses: Vec<usize> = Vec::new();
        {
            let cache = self.cache.lock().unwrap();
            for i in 0..n {
                match cache.get(&(keys[i], fp)) {
                    Some(&verdict) => ok[i] = verdict,
                    None => misses.push(i),
                }
            }
        }
        if admission {
            self.stats.admit_txs.fetch_add(n as u64, Ordering::Relaxed);
            self.stats
                .admit_cache_hits
                .fetch_add((n - misses.len()) as u64, Ordering::Relaxed);
        } else {
            self.stats.cache_hits.fetch_add((n - misses.len()) as u64, Ordering::Relaxed);
            self.stats.cache_misses.fetch_add(misses.len() as u64, Ordering::Relaxed);
        }

        if misses.is_empty() {
            return (ok, misses);
        }
        let verify = |e: &SharedEnvelope| {
            let payload = endorsement_payload(&e.tx_id(), &e.rw_digest());
            policy.satisfied_prehashed(&payload, e.endorsements(), ca)
        };
        let verdicts: Vec<(usize, bool)> = match &self.pool {
            Some(pool) if misses.len() > 1 => {
                // Chunk the misses across the workers; each chunk sends
                // its verdicts back over a per-call channel, so
                // concurrent calls never wait on each other's jobs.
                let per_chunk = misses.len().div_ceil(self.workers);
                let (tx, rx) = mpsc::channel::<Vec<(usize, bool)>>();
                let mut jobs = 0usize;
                for chunk in misses.chunks(per_chunk) {
                    // Refcount bumps only: each worker owns handles to the
                    // shared buffers, not copies of the payloads.
                    let chunk: Vec<(usize, SharedEnvelope)> =
                        chunk.iter().map(|&i| (i, envs[i].clone())).collect();
                    let policy = policy.clone();
                    let ca = ca.clone();
                    let tx = tx.clone();
                    jobs += 1;
                    pool.execute(move || {
                        let out: Vec<(usize, bool)> = chunk
                            .into_iter()
                            .map(|(i, e)| {
                                let payload =
                                    endorsement_payload(&e.tx_id(), &e.rw_digest());
                                let sat = policy.satisfied_prehashed(
                                    &payload,
                                    e.endorsements(),
                                    &ca,
                                );
                                (i, sat)
                            })
                            .collect();
                        let _ = tx.send(out);
                    });
                }
                drop(tx);
                let mut all = Vec::with_capacity(misses.len());
                for _ in 0..jobs {
                    all.extend(rx.recv().expect("validation worker dropped its result"));
                }
                all
            }
            _ => misses.iter().map(|&i| (i, verify(&envs[i]))).collect(),
        };
        let mut cache = self.cache.lock().unwrap();
        if cache.len() + verdicts.len() > CACHE_CAP {
            // Crude but bounded: committed blocks never revalidate, so
            // a cold cache only costs the in-flight replicas one redo.
            cache.clear();
        }
        for &(i, verdict) in &verdicts {
            ok[i] = verdict;
            cache.insert((keys[i], fp), verdict);
        }
        drop(cache);
        (ok, misses)
    }

    /// Stage-2 report from a peer: serial-stage wall time plus the block's
    /// final validation codes (conflict/failure tallies come from here so
    /// the snapshot reflects committed outcomes, not pre-verdicts).
    pub fn note_apply(&self, nanos: u64, codes: &[ValidationCode]) {
        self.stats.blocks.fetch_add(1, Ordering::Relaxed);
        self.stats.txs.fetch_add(codes.len() as u64, Ordering::Relaxed);
        self.stats.apply_nanos.fetch_add(nanos, Ordering::Relaxed);
        let mvcc = codes.iter().filter(|c| **c == ValidationCode::MvccConflict).count();
        let pol =
            codes.iter().filter(|c| **c == ValidationCode::EndorsementPolicyFailure).count();
        if mvcc > 0 {
            self.stats.mvcc_conflicts.fetch_add(mvcc as u64, Ordering::Relaxed);
        }
        if pol > 0 {
            self.stats.policy_failures.fetch_add(pol as u64, Ordering::Relaxed);
        }
    }

    /// Register both stages' counters with a telemetry registry (weakly —
    /// pruned once the owning ordering service / peer is gone).
    pub fn register_telemetry(self: &Arc<Self>, registry: &telemetry::Registry) {
        let weak = Arc::downgrade(self);
        registry.register(move || {
            let v = weak.upgrade()?;
            let s = v.snapshot();
            Some(vec![
                Sample::counter("scalesfl_validator_blocks_total", Vec::new(), s.blocks as f64),
                Sample::counter("scalesfl_validator_txs_total", Vec::new(), s.txs as f64),
                Sample::counter(
                    "scalesfl_validator_prevalidate_seconds_total",
                    Vec::new(),
                    s.prevalidate_s(),
                ),
                Sample::counter("scalesfl_validator_apply_seconds_total", Vec::new(), s.apply_s()),
                Sample::counter(
                    "scalesfl_validator_cache_hits_total",
                    Vec::new(),
                    s.cache_hits as f64,
                ),
                Sample::counter(
                    "scalesfl_validator_cache_misses_total",
                    Vec::new(),
                    s.cache_misses as f64,
                ),
                Sample::counter(
                    "scalesfl_validator_mvcc_conflicts_total",
                    Vec::new(),
                    s.mvcc_conflicts as f64,
                ),
                Sample::counter(
                    "scalesfl_validator_policy_failures_total",
                    Vec::new(),
                    s.policy_failures as f64,
                ),
                Sample::counter(
                    "scalesfl_validator_admit_txs_total",
                    Vec::new(),
                    s.admit_txs as f64,
                ),
                Sample::counter(
                    "scalesfl_validator_admit_cache_hits_total",
                    Vec::new(),
                    s.admit_cache_hits as f64,
                ),
            ])
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::msp::MemberId;
    use crate::ledger::tx::{Endorsement, Envelope, Proposal, RwSet};
    use crate::util::prng::Prng;

    fn signed_envelopes(
        ca: &CertificateAuthority,
        n: usize,
        endorsers: usize,
    ) -> (EndorsementPolicy, Vec<Envelope>) {
        let mut rng = Prng::new(17);
        let creds: Vec<_> = (0..endorsers)
            .map(|i| ca.enroll(MemberId::new(format!("org{i}.peer")), &mut rng))
            .collect();
        let members: Vec<MemberId> = creds.iter().map(|c| c.member.clone()).collect();
        let policy = EndorsementPolicy::MajorityOf(members);
        let envs: Vec<Envelope> = (0..n as u64)
            .map(|nonce| {
                let proposal = Proposal {
                    channel: "ch".into(),
                    chaincode: "kv".into(),
                    function: "Put".into(),
                    args: vec![format!("k{nonce}")],
                    creator: MemberId::new("client"),
                    nonce,
                };
                let mut env =
                    Envelope { proposal, rw_set: RwSet::default(), endorsements: vec![] };
                let payload = endorsement_payload(&env.tx_id(), &env.rw_set.digest());
                for c in &creds {
                    env.endorsements.push(Endorsement {
                        endorser: c.member.clone(),
                        signature: c.sign(&payload),
                    });
                }
                env
            })
            .collect();
        (policy, envs)
    }

    #[test]
    fn parallel_verdicts_match_serial() {
        let ca = CertificateAuthority::new();
        let (policy, mut envs) = signed_envelopes(&ca, 12, 4);
        // Corrupt a few: drop endorsements on 3, forge a signature on 7.
        envs[3].endorsements.truncate(1);
        envs[7].endorsements[0].signature.0[0] ^= 0xFF;
        let envs: Vec<SharedEnvelope> = envs.into_iter().map(Into::into).collect();
        let serial = BlockValidator::serial();
        let parallel = BlockValidator::new(4);
        let a = serial.prevalidate(&policy, &ca, &envs);
        let b = parallel.prevalidate(&policy, &ca, &envs);
        assert_eq!(a, b);
        assert!(a[0] && a[11]);
        assert!(!a[3] && !a[7]);
    }

    #[test]
    fn cache_shares_verdicts_across_replicas() {
        let ca = CertificateAuthority::new();
        let (policy, envs) = signed_envelopes(&ca, 8, 3);
        let envs: Vec<SharedEnvelope> = envs.into_iter().map(Into::into).collect();
        let v = BlockValidator::new(2);
        let first = v.prevalidate(&policy, &ca, &envs);
        let snap = v.snapshot();
        assert_eq!(snap.cache_misses, 8);
        assert_eq!(snap.cache_hits, 0);
        // Replica 2..N of the same block: all verdicts served from cache.
        let second = v.prevalidate(&policy, &ca, &envs);
        assert_eq!(first, second);
        let snap = v.snapshot();
        assert_eq!(snap.cache_misses, 8);
        assert_eq!(snap.cache_hits, 8);
    }

    #[test]
    fn admission_verdicts_prime_the_commit_cache() {
        let ca = CertificateAuthority::new();
        let (policy, envs) = signed_envelopes(&ca, 6, 3);
        let envs: Vec<SharedEnvelope> = envs.into_iter().map(Into::into).collect();
        let v = BlockValidator::new(2);
        let at_admission = v.admission_verify(&policy, &ca, &envs);
        assert!(at_admission.iter().all(|&b| b));
        let snap = v.snapshot();
        assert_eq!(snap.admit_txs, 6);
        assert_eq!(snap.admit_cache_hits, 0);
        assert_eq!(snap.cache_misses, 0, "commit counters untouched by admission");
        // The block later prevalidates entirely from cached admission
        // verdicts — the crypto ran once, at the pool boundary.
        let at_commit = v.prevalidate(&policy, &ca, &envs);
        assert_eq!(at_admission, at_commit);
        let snap = v.snapshot();
        assert_eq!(snap.cache_hits, 6);
        assert_eq!(snap.cache_misses, 0);
    }

    #[test]
    fn policy_change_invalidates_cached_verdicts() {
        let ca = CertificateAuthority::new();
        let (policy, envs) = signed_envelopes(&ca, 2, 3);
        let envs: Vec<SharedEnvelope> = envs.into_iter().map(Into::into).collect();
        let v = BlockValidator::serial();
        assert!(v.prevalidate(&policy, &ca, &envs).iter().all(|&b| b));
        // A stricter policy (more required signers than exist) must not be
        // answered from the old policy's cached verdicts.
        let strict = EndorsementPolicy::AnyOf(5, policy.members().to_vec());
        assert!(v.prevalidate(&strict, &ca, &envs).iter().all(|&b| !b));
    }

    #[test]
    fn note_apply_tallies_outcomes() {
        let v = BlockValidator::serial();
        v.note_apply(
            1_500,
            &[
                ValidationCode::Valid,
                ValidationCode::MvccConflict,
                ValidationCode::EndorsementPolicyFailure,
                ValidationCode::MvccConflict,
            ],
        );
        let snap = v.snapshot();
        assert_eq!(snap.blocks, 1);
        assert_eq!(snap.txs, 4);
        assert_eq!(snap.apply_nanos, 1_500);
        assert_eq!(snap.mvcc_conflicts, 2);
        assert_eq!(snap.policy_failures, 1);
        assert!(snap.to_json().get("mvcc_conflicts").is_some());
    }
}
