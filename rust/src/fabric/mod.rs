//! Fabric-style permissioned ledger: the execute–order–validate pipeline.
//!
//! - **Execute**: clients send proposals to *endorsing peers*, which run the
//!   chaincode (including the model-evaluation defence policy — the paper's
//!   endorsement bottleneck) against current state, producing signed
//!   read/write sets ([`peer`], [`chaincode`]).
//! - **Order**: assembled envelopes pass admission control into the
//!   per-channel mempool (`crate::mempool`: bounded priority lanes, rate
//!   caps, explicit backpressure); the ordering service pulls
//!   size-and-byte-bounded batches and replicates them through Raft (or
//!   PBFT) consensus, while a committer thread pipelines validation
//!   ([`orderer`]).
//! - **Validate**: every peer independently validates delivered blocks in
//!   two stages ([`peer`], [`validator`]): parallel endorsement-policy /
//!   signature pre-validation (fanned out over a worker pool, with a
//!   verdict cache shared across replicas of the same block) followed by
//!   the serial MVCC read-version check + state apply under the state
//!   write lock. Per-stage timings export via
//!   [`validator::ValidationSnapshot`].
//!
//! Clients drive the pipeline through the non-blocking submission API:
//! [`gateway::Gateway::submit`] returns a [`gateway::SubmitHandle`] and the
//! per-channel [`waiter::CommitWaiter`] demux routes each commit event to
//! the one handle awaiting it — thousands of transactions stay in flight
//! per channel over a single commit-event subscription. A gateway bound
//! to a shard ingress ([`gateway::Gateway::ingress`]) submits through
//! that shard's pool; envelopes homed elsewhere ride the orderer's
//! cross-shard relay (`crate::mempool::relay`), and relay losses resolve
//! the handle through [`waiter::WaiterEvent::Dropped`].
//!
//! **One encode, refcounts everywhere.** An envelope is serialized to its
//! canonical wire bytes exactly once, when it enters the pipeline; from
//! then on every stage passes a [`crate::ledger::envelope::SharedEnvelope`]
//! — an `Arc`'d buffer with lazily-decoded, cached views (tx id, rw-set
//! digest, envelope digest, decoded body). The mempool queues hold
//! refcounts, the relay forwards the same buffer across hops, batch pull
//! and block cutting move handles, consensus payloads and the durable
//! ledger splice the buffer bytes straight into their frames
//! ([`wire::encode_batch`] / [`wire::encode_block`]), and
//! [`wire::decode_shared`] carves the envelopes of an incoming payload
//! back out as zero-copy spans of the one allocation. Validation hashes
//! are cached-view reads, so [`validator::BlockValidator`] worker threads
//! and replica peers share verdict keys without re-hashing — and its
//! (envelope digest, policy fingerprint) verdict cache is shared with
//! mempool admission (`BlockValidator::admission_verify`), so a
//! transaction crypto-verified when it entered the pool prevalidates for
//! free when its block commits.
//!
//! Channels model shards (paper §4): one channel per shard plus the
//! mainchain channel every peer joins.

pub mod chaincode;
pub mod endorsement;
pub mod gateway;
pub mod orderer;
pub mod peer;
pub mod validator;
pub mod waiter;
pub mod wire;

pub use chaincode::{Chaincode, TxContext};
pub use endorsement::EndorsementPolicy;
pub use gateway::{CommitOutcome, Gateway, SubmitHandle};
pub use orderer::{OrdererConfig, OrderingService};
pub use peer::{CommitEvent, Peer, PeerChannel, Subscription};
pub use validator::{BlockValidator, ValidationSnapshot};
pub use waiter::{CommitWaiter, WaiterEvent};
