//! The ordering service: batches endorsed envelopes into blocks through a
//! Raft or PBFT replica cluster (the paper's orderer) and delivers committed
//! blocks to every peer on the batch's channel.
//!
//! Replica messaging is no longer an instant in-memory exchange: the driver
//! owns a [`Cluster`](crate::consensus::Cluster) whose messages ride
//! `network::simnet` links via [`crate::consensus::Transport`], with
//! per-link latency, reordering, and — when
//! [`OrdererConfig::consensus_faults`] is set — scheduled crashes,
//! partitions, message loss, and Byzantine equivocation from a seeded
//! [`FaultPlan`]. The driver re-proposes uncommitted payloads after every
//! epoch change (leader election / view change), and when no leader is
//! reachable it plays the PBFT client: due batches are broadcast to the
//! replicas as pending requests (then returned to the pool) so a dead
//! primary's backups still trigger the view change. Replayed payloads
//! validate as `DuplicateTxId` on every replica, keeping chains identical.
//!
//! Ingress goes through the sharded mempool (`crate::mempool`): `submit`
//! routes envelopes into the per-channel pool (admission control, priority
//! lanes, MVCC staleness hinting, explicit backpressure), and the driver
//! thread *pulls* size-and-byte-bounded batches from the pools instead of
//! owning batching state. Block production is pipelined: the driver runs
//! consensus while a separate committer thread validates and applies
//! delivered blocks, so batch cutting, ordering, and validation overlap.
//!
//! The committer drives the two-stage validation pipeline: one shared
//! [`BlockValidator`] (sized by [`OrdererConfig::validation_workers`])
//! fans the endorsement-policy crypto out across its worker pool and lets
//! every peer replica of a block reuse the first replica's cached
//! verdicts; per-stage timings export via
//! [`OrderingService::validation_stats`]. On startup the orderer also
//! wires each channel's mempool to a replica's read-version oracle, so
//! admission can shed transactions that are already guaranteed to fail
//! MVCC at commit.
//!
//! With [`OrdererConfig::relay`] set, the driver also runs the
//! cross-shard relay (`crate::mempool::relay`): gateways bound to a shard
//! ingress ([`OrderingService::submit_from`]) feed misrouted and
//! checkpoint traffic into that shard's pool, and the driver pumps due
//! hops into their home pools at the top of every tick — each hop priced
//! by a `network::simnet` link latency — so batch pulls and block cutting
//! see realistic cross-shard arrival skew.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use crate::consensus::pbft::{self, Pbft, PbftConfig};
use crate::consensus::raft::{Raft, RaftConfig};
use crate::consensus::{Cluster, ClusterStats, ConsensusNode, FaultPlan, TransportConfig};
use crate::crypto::{sha256, Digest};
use crate::ledger::envelope::SharedEnvelope;
use crate::ledger::state::StateView;
use crate::ledger::store::LedgerConfig;
use crate::mempool::{MempoolConfig, MempoolRegistry, Reject, Relay, RelayConfig};
use crate::util::clock::SystemClock;
use crate::util::prng::Prng;

use super::peer::Peer;
use super::validator::{BlockValidator, ValidationSnapshot};
use super::wire;

/// Which consensus protocol orders blocks (the paper's §3.2 pluggable
/// consensus: Raft for trusted/small shards, PBFT for byzantine settings).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ConsensusKind {
    Raft,
    Pbft,
}

/// Ordering service configuration.
#[derive(Clone, Debug)]
pub struct OrdererConfig {
    /// Envelopes per block before a cut is forced.
    pub batch_size: usize,
    /// Max serialized bytes per block (0 = unbounded). The pool enforces
    /// this when the driver pulls a batch.
    pub batch_bytes: usize,
    /// Max time the first pending envelope waits before a cut.
    pub batch_timeout: Duration,
    /// Minimum spacing between consecutive block proposals (models finite
    /// consensus bandwidth; zero = cut as fast as batches are due). This is
    /// what makes the ordering stage a measurable knee in surge benches.
    pub min_block_interval: Duration,
    /// Consensus cluster size (1 = the paper's single orderer).
    pub consensus_nodes: usize,
    /// Ordering protocol.
    pub consensus: ConsensusKind,
    /// Latency profile for the replica-to-replica links. Consensus
    /// messages (elections, heartbeats, PBFT phases) are queued through a
    /// `network::simnet` link oracle instead of exchanging instantly;
    /// defaults to a same-rack profile (~0.5–2.5 ms per hop).
    pub consensus_net: TransportConfig,
    /// Scheduled fault injection for the consensus cluster (crashes,
    /// partitions, message drops/delays, Byzantine equivocation), timed
    /// on the driver's clock. `None` = fault-free.
    pub consensus_faults: Option<FaultPlan>,
    /// Driver loop granularity.
    pub tick: Duration,
    /// Worker threads for the parallel pre-validation stage of block
    /// commit (1 = verify inline on the committer thread; the cross-peer
    /// verdict cache is shared either way).
    pub validation_workers: usize,
    /// Cross-shard relay between the per-channel pools. `Some` lets
    /// gateways bind to a shard ingress (`Gateway::ingress`): misrouted
    /// envelopes and shard-produced checkpoint traffic hop to their home
    /// pool over per-link simnet latencies, pumped by the driver each
    /// tick so batch pulls see the skewed arrivals. `None` keeps the
    /// idealized direct router.
    pub relay: Option<RelayConfig>,
    /// Durable ledger (`crate::ledger::store`). `Some` attaches a
    /// per-peer, per-channel block log + snapshot store to every joined
    /// channel at startup — recovering previously persisted state by
    /// replay — and persists each committed block. `None` keeps replicas
    /// purely in-memory (the historical behavior).
    pub ledger: Option<LedgerConfig>,
}

impl Default for OrdererConfig {
    fn default() -> Self {
        OrdererConfig {
            batch_size: 10,
            batch_bytes: 512 * 1024,
            batch_timeout: Duration::from_millis(100),
            min_block_interval: Duration::ZERO,
            consensus_nodes: 1,
            consensus: ConsensusKind::Raft,
            consensus_net: TransportConfig::default(),
            consensus_faults: None,
            tick: Duration::from_millis(2),
            validation_workers: 1,
            relay: None,
            ledger: None,
        }
    }
}

/// Handle to the running ordering service.
pub struct OrderingService {
    mempool: Arc<MempoolRegistry>,
    shutdown: Arc<AtomicBool>,
    driver: Option<thread::JoinHandle<()>>,
    committer: Option<thread::JoinHandle<()>>,
    blocks_cut: Arc<AtomicU64>,
    /// Committed consensus payloads that failed to decode (satellite of
    /// the durability work: a committed-but-undeliverable batch is data
    /// loss and must be visible, not an `eprintln!` in the void).
    bad_batches: Arc<AtomicU64>,
    /// Shared two-stage validator: worker pool + cross-peer verdict cache.
    validator: Arc<BlockValidator>,
    /// Cross-shard relay, pumped by the driver (None = direct routing).
    relay: Option<Arc<Relay>>,
    /// Live consensus bookkeeping, refreshed by the driver every tick.
    consensus_stats: Arc<Mutex<ClusterStats>>,
}

impl OrderingService {
    /// Start the orderer with a default (admission-precheck-off) mempool;
    /// committed blocks are delivered to every peer in `peers` that joined
    /// the batch's channel.
    pub fn start(cfg: OrdererConfig, peers: Vec<Arc<Peer>>, seed: u64) -> Arc<OrderingService> {
        OrderingService::start_with_mempool(
            cfg,
            peers,
            seed,
            MempoolRegistry::new(MempoolConfig::default()),
        )
    }

    /// Start the orderer over an externally configured mempool registry
    /// (admission control, rate caps, per-channel policies).
    pub fn start_with_mempool(
        cfg: OrdererConfig,
        peers: Vec<Arc<Peer>>,
        seed: u64,
        mempool: Arc<MempoolRegistry>,
    ) -> Arc<OrderingService> {
        let shutdown = Arc::new(AtomicBool::new(false));
        let blocks_cut = Arc::new(AtomicU64::new(0));
        let bad_batches = Arc::new(AtomicU64::new(0));
        let validator = Arc::new(BlockValidator::new(cfg.validation_workers));

        // Durable ledger: attach each peer's per-channel store before any
        // thread starts committing, so recovery-by-replay runs on quiescent
        // replicas and every subsequent commit is persisted.
        if let Some(lcfg) = &cfg.ledger {
            for p in &peers {
                for name in p.channel_names() {
                    if let Err(e) = p.attach_store(&name, lcfg) {
                        eprintln!("orderer: attach store {}/{name}: {e}", p.member);
                    }
                }
            }
        }
        let relay = cfg
            .relay
            .clone()
            .map(|rc| Relay::new(Arc::clone(&mempool), rc, SystemClock::shared()));

        // Expose the whole pipeline through the process-wide metrics
        // registry. Every collector captures weakly, so a torn-down
        // network prunes itself from the registry.
        let registry = crate::telemetry::global().registry();
        mempool.register_telemetry(registry);
        validator.register_telemetry(registry);
        if let Some(relay) = &relay {
            relay.register_telemetry(registry);
        }
        {
            let weak = Arc::downgrade(&blocks_cut);
            registry.register(move || {
                let cut = weak.upgrade()?;
                Some(vec![crate::telemetry::Sample::counter(
                    "scalesfl_orderer_blocks_cut_total",
                    Vec::new(),
                    cut.load(Ordering::Relaxed) as f64,
                )])
            });
        }
        {
            let weak = Arc::downgrade(&bad_batches);
            registry.register(move || {
                let bad = weak.upgrade()?;
                Some(vec![crate::telemetry::Sample::counter(
                    "scalesfl_orderer_bad_batches_total",
                    Vec::new(),
                    bad.load(Ordering::Relaxed) as f64,
                )])
            });
        }

        // Admission-side MVCC hinting: wire every already-joined channel
        // now (covers state seeded by direct `commit_batch` before the
        // orderer saw a block); channels joined later are wired by the
        // committer at their first ordered block — the moment their
        // state first becomes non-trivial.
        for p in &peers {
            for name in p.channel_names() {
                wire_state_view(&mempool, &peers, &name);
            }
        }

        // Pipeline stage 3: validation/commit runs off the consensus
        // thread, through the shared two-stage validator (parallel policy
        // pre-validation once per block, serial MVCC+apply per replica).
        let (commit_tx, commit_rx) = mpsc::channel::<(String, Vec<SharedEnvelope>)>();
        let committer = {
            let counter = Arc::clone(&blocks_cut);
            let validator = Arc::clone(&validator);
            let mempool = Arc::clone(&mempool);
            thread::Builder::new()
                .name("orderer-committer".into())
                .spawn(move || {
                    while let Ok((channel, envs)) = commit_rx.recv() {
                        counter.fetch_add(1, Ordering::Relaxed);
                        wire_state_view(&mempool, &peers, &channel);
                        for p in &peers {
                            if p.channel(&channel).is_some() {
                                if let Err(e) =
                                    p.commit_batch_with(&validator, &channel, envs.clone())
                                {
                                    eprintln!("orderer: commit failed on {}: {e}", p.member);
                                }
                            }
                        }
                    }
                })
                .expect("spawn orderer committer")
        };

        let consensus_stats = Arc::new(Mutex::new(ClusterStats::default()));
        let driver = {
            let mempool = Arc::clone(&mempool);
            let stop = Arc::clone(&shutdown);
            let relay = relay.clone();
            let bad = Arc::clone(&bad_batches);
            let stats_out = Arc::clone(&consensus_stats);
            thread::Builder::new()
                .name("orderer".into())
                .spawn(move || {
                    let n = cfg.consensus_nodes.max(1);
                    let mut rng = Prng::new(seed);
                    let plan = cfg.consensus_faults.clone().unwrap_or_default();
                    let registry = crate::telemetry::global().registry();
                    match cfg.consensus {
                        ConsensusKind::Raft => {
                            let nodes: Vec<Raft> = (0..n)
                                .map(|i| {
                                    Raft::new(i, n, RaftConfig::default(), rng.fork(i as u64))
                                })
                                .collect();
                            let cluster = Cluster::new(nodes, &cfg.consensus_net, &plan);
                            cluster.telemetry().register(registry, "raft");
                            driver(cfg, mempool, stop, commit_tx, relay, bad, stats_out, cluster)
                        }
                        ConsensusKind::Pbft => {
                            let nodes: Vec<Pbft> =
                                (0..n).map(|i| Pbft::new(i, n, PbftConfig::default())).collect();
                            let mut cluster = Cluster::new(nodes, &cfg.consensus_net, &plan);
                            if plan.has_equivocation() {
                                // The scheduled Byzantine replica forges a
                                // per-destination variant of each pre-prepare.
                                cluster.set_mutator(Box::new(pbft::equivocate));
                            }
                            cluster.telemetry().register(registry, "pbft");
                            driver(cfg, mempool, stop, commit_tx, relay, bad, stats_out, cluster)
                        }
                    }
                })
                .expect("spawn orderer")
        };

        Arc::new(OrderingService {
            mempool,
            shutdown,
            driver: Some(driver),
            committer: Some(committer),
            blocks_cut,
            bad_batches,
            validator,
            relay,
            consensus_stats,
        })
    }

    /// Submit an endorsed envelope for ordering, routed straight to its
    /// home channel's pool. `Err` is explicit backpressure from admission
    /// control — the envelope was *not* queued. Accepts anything
    /// convertible to the canonical [`SharedEnvelope`]; callers already
    /// holding one (gateways, the node server) pay no re-encode.
    pub fn submit(&self, env: impl Into<SharedEnvelope>) -> Result<(), Reject> {
        self.submit_from(None, env)
    }

    /// Submit through a shard's ingress pool. With a relay running and
    /// `ingress` set, an envelope whose home channel differs from the
    /// ingress is admitted for forwarding and hops home over a simnet
    /// link latency; otherwise this is [`OrderingService::submit`].
    pub fn submit_from(
        &self,
        ingress: Option<&str>,
        env: impl Into<SharedEnvelope>,
    ) -> Result<(), Reject> {
        if self.shutdown.load(Ordering::Relaxed) {
            return Err(Reject::Shutdown);
        }
        let env = env.into();
        match (&self.relay, ingress) {
            (Some(relay), Some(local)) => relay.ingress(local, env),
            _ => self.mempool.submit_shared(env),
        }
    }

    /// The cross-shard relay, when configured.
    pub fn relay(&self) -> Option<&Arc<Relay>> {
        self.relay.as_ref()
    }

    /// The ingress pools (per-channel policies, reject/overflow counters).
    pub fn mempool(&self) -> &Arc<MempoolRegistry> {
        &self.mempool
    }

    pub fn blocks_cut(&self) -> u64 {
        self.blocks_cut.load(Ordering::Relaxed)
    }

    /// Committed consensus payloads that failed to decode (each one is a
    /// batch the peers never saw — should stay 0 outside fault injection).
    pub fn bad_batches(&self) -> u64 {
        self.bad_batches.load(Ordering::Relaxed)
    }

    /// The shared block validator (worker pool + verdict cache) the
    /// committer drives.
    pub fn validator(&self) -> &Arc<BlockValidator> {
        &self.validator
    }

    /// Per-stage validation counters: pre-validate vs apply wall time,
    /// cache hit rate, and commit-time conflict tallies.
    pub fn validation_stats(&self) -> ValidationSnapshot {
        self.validator.snapshot()
    }

    /// Snapshot of the consensus cluster: epoch/leader churn, commit and
    /// divergence tallies, and the transport's message accounting. The
    /// driver refreshes it every tick; `driver_lost()` staying 0 is the
    /// transport's no-silent-drops invariant.
    pub fn consensus_stats(&self) -> ClusterStats {
        self.consensus_stats.lock().unwrap().clone()
    }
}

impl Drop for OrderingService {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
        self.mempool.close_all();
        if let Some(h) = self.driver.take() {
            let _ = h.join();
        }
        // The driver has stopped pumping: flush in-flight relay hops as
        // Shutdown drops so no submit handle pends forever on a hop that
        // will never land.
        if let Some(relay) = &self.relay {
            relay.close();
        }
        // The driver owned the commit sender; once it exits the committer
        // drains the channel and stops.
        if let Some(h) = self.committer.take() {
            let _ = h.join();
        }
    }
}

/// Give `channel`'s pool a replica's read-version oracle for MVCC
/// staleness hinting (no-op once wired — an explicitly configured view is
/// never replaced). The first peer that joined the channel speaks for all
/// replicas; a lagging view only under-hints, never mis-rejects.
fn wire_state_view(mempool: &MempoolRegistry, peers: &[Arc<Peer>], channel: &str) {
    let pool = mempool.pool(channel);
    if pool.has_state_view() {
        return;
    }
    if let Some(ch) = peers.iter().find_map(|p| p.channel(channel)) {
        pool.set_state_view(ch as Arc<dyn StateView>);
    }
}

/// Fair round-robin cursor over the per-channel pools.
///
/// The cursor persists across driver ticks and only advances past a
/// channel when that channel actually received service (a block was cut).
/// The previous scheme rotated the drain order once per *tick*, which
/// aliases with `min_block_interval` throttling: when the interval spans an
/// even number of ticks, the same channel leads the order at every moment
/// bandwidth is available, and a saturated shard starves the others.
/// Throttled ticks (no cut) must not rotate the order at all.
///
/// Tracks the last-served channel by *name*, not index: pools are created
/// lazily, and a new channel sorting ahead of existing ones would shift
/// every index and hand the just-served channel another turn.
#[derive(Debug, Default)]
struct ChannelCursor {
    last_served: Option<String>,
}

impl ChannelCursor {
    /// Visit order over the sorted channel list for this opportunity:
    /// starts at the sorted successor of the last-served name.
    fn order(&self, channels: &[String]) -> Vec<usize> {
        let n = channels.len();
        if n == 0 {
            return Vec::new();
        }
        let start = match &self.last_served {
            Some(last) => channels.iter().position(|c| c > last).unwrap_or(0),
            None => 0,
        };
        (0..n).map(|off| (start + off) % n).collect()
    }

    /// Channel `name` was just served a block: the next opportunity starts
    /// with its successor.
    fn served(&mut self, name: &str) {
        if self.last_served.as_deref() != Some(name) {
            self.last_served = Some(name.to_string());
        }
    }
}

/// Hand one committed consensus payload to the committer. A payload that
/// fails to decode is *counted* (and logged) instead of silently dropped —
/// a committed-but-undeliverable batch is data loss the operator must see.
/// Returns `false` only when the committer is gone (shutdown).
fn deliver_committed(
    data: &[u8],
    commit_tx: &mpsc::Sender<(String, Vec<SharedEnvelope>)>,
    bad_batches: &AtomicU64,
) -> bool {
    match wire::decode_batch(data) {
        Ok(pair) => commit_tx.send(pair).is_ok(),
        Err(e) => {
            bad_batches.fetch_add(1, Ordering::Relaxed);
            eprintln!("orderer: bad batch payload: {e}");
            true
        }
    }
}

fn driver<C: ConsensusNode>(
    cfg: OrdererConfig,
    mempool: Arc<MempoolRegistry>,
    shutdown: Arc<AtomicBool>,
    commit_tx: mpsc::Sender<(String, Vec<SharedEnvelope>)>,
    relay: Option<Arc<Relay>>,
    bad_batches: Arc<AtomicU64>,
    stats_out: Arc<Mutex<ClusterStats>>,
    mut cluster: Cluster<C>,
) {
    let start = Instant::now();
    let mut last_cut = f64::NEG_INFINITY;
    let mut last_nudge = f64::NEG_INFINITY;
    let min_interval = cfg.min_block_interval.as_secs_f64();
    // Round-robin service across channels; advances only on actual cuts so
    // a saturated channel cannot starve the others under throttling.
    let mut cursor = ChannelCursor::default();
    // Proposed-but-uncommitted payloads, keyed by digest. A leader crash
    // (or PBFT view change) can strand an accepted proposal in the dead
    // leader's log, so after every epoch change the survivors get the
    // whole set again. Re-proposing an already-committed payload is safe:
    // the replayed envelopes validate as DuplicateTxId and every replica
    // applies the same verdicts, so chains stay identical.
    let mut outstanding: HashMap<Digest, (String, Vec<u8>)> = HashMap::new();
    let mut reproposed_epoch = 0u64;

    loop {
        if shutdown.load(Ordering::Relaxed) {
            return;
        }
        thread::sleep(cfg.tick);
        let now = start.elapsed().as_secs_f64();

        // Deliver due cross-shard hops into their home pools *before*
        // batch pulls: block cutting sees relayed arrivals at their
        // latency-skewed times, not whenever a client happened to submit.
        if let Some(relay) = &relay {
            relay.pump();
        }

        // Consensus housekeeping: fault schedule, node ticks, and
        // delivery of replica messages that have served their link latency.
        cluster.tick(now);

        // Leadership moved: re-propose everything still uncommitted. Only
        // advance the watermark when the whole set went through, so a
        // propose refused mid-handover is retried next tick.
        let epoch = cluster.epoch();
        if epoch > reproposed_epoch {
            let all_ok = outstanding
                .values()
                .all(|(channel, payload)| cluster.propose(channel, payload.clone(), now).is_ok());
            if all_ok {
                reproposed_epoch = epoch;
            }
        }

        // Pull due batches from the per-channel pools and propose them,
        // round-robin across channels.
        if cluster.leader().is_some() {
            let channels = mempool.channels();
            'channels: for idx in cursor.order(&channels) {
                let channel = &channels[idx];
                let Some(pool) = mempool.get(channel) else { continue };
                while pool.ready(cfg.batch_size, cfg.batch_timeout) {
                    if min_interval > 0.0 && now - last_cut < min_interval {
                        // Consensus bandwidth exhausted for this tick; the
                        // pools keep absorbing (and, at capacity, shedding).
                        // The cursor stays put: un-served channels keep
                        // their place at the head of the next opportunity.
                        break 'channels;
                    }
                    let envs = pool.take_batch(cfg.batch_size, cfg.batch_bytes);
                    if envs.is_empty() {
                        break;
                    }
                    let payload = wire::encode_batch(channel, &envs);
                    if cluster.propose(channel, payload.clone(), now).is_err() {
                        // Leadership moved; re-queue and retry next tick.
                        pool.restore(envs);
                        break 'channels;
                    }
                    outstanding.insert(sha256(&payload), (channel.clone(), payload));
                    last_cut = now;
                    cursor.served(channel);
                }
            }
        } else if now - last_nudge >= cfg.batch_timeout.as_secs_f64() {
            // No usable leader. Play the PBFT client: show every alive
            // replica the next due batch so their request timers run — a
            // crashed primary only gets voted out if the backups know work
            // is waiting — then put the envelopes back. They are proposed
            // for real (and tracked in `outstanding`) once a leader exists;
            // the planted copy, if a view change commits it first, replays
            // as DuplicateTxId. Raft replicas ignore the nudge entirely;
            // their election timers alone restore a leader.
            last_nudge = now;
            let channels = mempool.channels();
            for idx in cursor.order(&channels) {
                let channel = &channels[idx];
                let Some(pool) = mempool.get(channel) else { continue };
                if !pool.ready(cfg.batch_size, cfg.batch_timeout) {
                    continue;
                }
                let envs = pool.take_batch(cfg.batch_size, cfg.batch_bytes);
                if envs.is_empty() {
                    continue;
                }
                let payload = wire::encode_batch(channel, &envs);
                cluster.broadcast_request(channel, payload, now);
                pool.restore(envs);
            }
        }

        // Hand committed batches to the committer thread (pipeline overlap:
        // the next tick's consensus work proceeds while peers validate).
        for data in cluster.take_committed(now) {
            outstanding.remove(&sha256(&data));
            if !deliver_committed(&data, &commit_tx, &bad_batches) {
                return;
            }
        }
        *stats_out.lock().unwrap() = cluster.stats();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::consensus::Fault;
    use crate::crypto::msp::{CertificateAuthority, MemberId};
    use crate::fabric::chaincode::{Chaincode, TxContext};
    use crate::fabric::endorsement::EndorsementPolicy;
    use crate::ledger::block::ValidationCode;
    use crate::ledger::tx::{Envelope, Proposal};

    struct PutAs(&'static str);
    impl Chaincode for PutAs {
        fn name(&self) -> &str {
            self.0
        }
        fn invoke(
            &self,
            ctx: &mut TxContext<'_>,
            _f: &str,
            args: &[String],
        ) -> Result<Vec<u8>, String> {
            ctx.put(&args[0], args[1].as_bytes().to_vec());
            Ok(vec![])
        }
    }

    fn network_with(
        n_peers: usize,
        cfg: OrdererConfig,
        mempool: Option<Arc<MempoolRegistry>>,
    ) -> (Vec<Arc<Peer>>, Arc<OrderingService>) {
        let ca = CertificateAuthority::new();
        let mut rng = Prng::new(1);
        let peers: Vec<Arc<Peer>> = (0..n_peers)
            .map(|i| {
                let cred = ca.enroll(MemberId::new(format!("org{i}.peer")), &mut rng);
                Peer::new(cred, ca.clone())
            })
            .collect();
        let members: Vec<MemberId> = peers.iter().map(|p| p.member.clone()).collect();
        for p in &peers {
            p.join_channel("ch", EndorsementPolicy::MajorityOf(members.clone()));
            p.install_chaincode("ch", Arc::new(PutAs("kv"))).unwrap();
            p.install_chaincode("ch", Arc::new(PutAs("catalyst"))).unwrap();
        }
        let orderer = match mempool {
            Some(m) => OrderingService::start_with_mempool(cfg, peers.clone(), 42, m),
            None => OrderingService::start(cfg, peers.clone(), 42),
        };
        (peers, orderer)
    }

    fn network(n_peers: usize, cfg: OrdererConfig) -> (Vec<Arc<Peer>>, Arc<OrderingService>) {
        network_with(n_peers, cfg, None)
    }

    fn endorsed_envelope_on(
        peers: &[Arc<Peer>],
        channel: &str,
        chaincode: &str,
        nonce: u64,
    ) -> Envelope {
        let prop = Proposal {
            channel: channel.into(),
            chaincode: chaincode.into(),
            function: "Put".into(),
            args: vec![format!("{chaincode}-k{nonce}"), "v".into()],
            creator: MemberId::new("client"),
            nonce,
        };
        let mut endorsements = Vec::new();
        let mut rw = None;
        for p in peers {
            let (r, e, _) = p.endorse(&prop).unwrap();
            rw = Some(r);
            endorsements.push(e);
        }
        Envelope { proposal: prop, rw_set: rw.unwrap(), endorsements }
    }

    fn endorsed_envelope_for(peers: &[Arc<Peer>], chaincode: &str, nonce: u64) -> Envelope {
        endorsed_envelope_on(peers, "ch", chaincode, nonce)
    }

    fn endorsed_envelope(peers: &[Arc<Peer>], nonce: u64) -> Envelope {
        endorsed_envelope_for(peers, "kv", nonce)
    }

    #[test]
    fn orders_and_commits_across_peers() {
        let (peers, orderer) = network(3, OrdererConfig::default());
        let rx = peers[2].subscribe("ch").unwrap();
        for nonce in 0..25 {
            orderer.submit(endorsed_envelope(&peers, nonce)).unwrap();
        }
        let mut got = 0;
        while got < 25 {
            let ev = rx.recv_timeout(Duration::from_secs(10)).expect("commit event");
            assert_eq!(ev.code, ValidationCode::Valid);
            got += 1;
        }
        for p in &peers {
            let ch = p.channel("ch").unwrap();
            assert_eq!(ch.scan("kv-k").len(), 25);
            ch.chain.lock().unwrap().verify().unwrap();
        }
        assert!(orderer.blocks_cut() >= 3); // batch_size 10 -> >= 3 blocks
        let stats = orderer.mempool().snapshot();
        assert_eq!(stats.admitted, 25);
        assert_eq!(stats.txs_ordered, 25);
        assert_eq!(stats.rejected_total(), 0);
        // Two-stage pipeline accounting: the first replica of each block
        // pays the signature crypto; the other two are answered from the
        // shared verdict cache (keys are per-envelope, so batching splits
        // don't change the counts).
        let vstats = orderer.validation_stats();
        assert_eq!(vstats.txs, 3 * 25, "3 replicas x 25 txs");
        assert_eq!(vstats.cache_misses, 25);
        assert_eq!(vstats.cache_hits, 2 * 25);
        assert_eq!(vstats.mvcc_conflicts, 0);
        assert!(vstats.prevalidate_nanos > 0 && vstats.apply_nanos > 0);
    }

    #[test]
    fn parallel_committer_stays_deterministic() {
        let cfg = OrdererConfig { validation_workers: 4, ..OrdererConfig::default() };
        let (peers, orderer) = network(3, cfg);
        let rx = peers[0].subscribe("ch").unwrap();
        for nonce in 0..20 {
            orderer.submit(endorsed_envelope(&peers, nonce)).unwrap();
        }
        for _ in 0..20 {
            let ev = rx.recv_timeout(Duration::from_secs(10)).expect("commit");
            assert_eq!(ev.code, ValidationCode::Valid);
        }
        assert_eq!(orderer.validator().workers(), 4);
        // Replicas validated through the parallel pool agree block-for-block.
        let chains: Vec<Vec<crate::crypto::Digest>> = peers
            .iter()
            .map(|p| {
                let ch = p.channel("ch").unwrap();
                let chain = ch.chain.lock().unwrap();
                chain.verify().unwrap();
                chain.iter().map(|b| b.hash()).collect()
            })
            .collect();
        assert!(!chains[0].is_empty());
        assert_eq!(chains[0], chains[1]);
        assert_eq!(chains[0], chains[2]);
    }

    #[test]
    fn batch_timeout_cuts_partial_blocks() {
        let cfg = OrdererConfig {
            batch_size: 100,
            batch_timeout: Duration::from_millis(30),
            ..OrdererConfig::default()
        };
        let (peers, orderer) = network(2, cfg);
        let rx = peers[0].subscribe("ch").unwrap();
        orderer.submit(endorsed_envelope(&peers, 1)).unwrap();
        let ev = rx.recv_timeout(Duration::from_secs(5)).expect("timeout cut");
        assert_eq!(ev.code, ValidationCode::Valid);
    }

    #[test]
    fn pbft_orderer_works() {
        let cfg = OrdererConfig {
            consensus: ConsensusKind::Pbft,
            consensus_nodes: 4,
            ..OrdererConfig::default()
        };
        let (peers, orderer) = network(2, cfg);
        let rx = peers[0].subscribe("ch").unwrap();
        for nonce in 0..8 {
            orderer.submit(endorsed_envelope(&peers, nonce)).unwrap();
        }
        for _ in 0..8 {
            let ev = rx.recv_timeout(Duration::from_secs(10)).expect("commit");
            assert_eq!(ev.code, ValidationCode::Valid);
        }
    }

    #[test]
    fn multi_node_raft_orderer_works() {
        let cfg = OrdererConfig { consensus_nodes: 3, ..OrdererConfig::default() };
        let (peers, orderer) = network(2, cfg);
        let rx = peers[1].subscribe("ch").unwrap();
        for nonce in 0..5 {
            orderer.submit(endorsed_envelope(&peers, nonce)).unwrap();
        }
        for _ in 0..5 {
            let ev = rx.recv_timeout(Duration::from_secs(10)).expect("commit");
            assert_eq!(ev.code, ValidationCode::Valid);
        }
        // The replica exchange now rides the simulated network: traffic
        // must have flowed, and the driver must not have lost any of it.
        let stats = orderer.consensus_stats();
        assert!(stats.transport.sent > 0, "replicas exchanged messages: {stats:?}");
        assert_eq!(stats.driver_lost(), 0, "no driver-dropped messages: {stats:?}");
        assert_eq!(stats.divergence, 0);
    }

    /// Tentpole integration scenario: a five-replica Raft orderer loses its
    /// leader in the middle of a 60-tx surge. Every transaction must still
    /// commit exactly once as Valid (re-proposals replay as DuplicateTxId),
    /// the survivors must re-elect, and all peers must end on byte-identical
    /// chains — the paper's safety claim, end to end through the mempool,
    /// simnet transport, fault injector, and parallel committer.
    #[test]
    fn leader_crash_mid_surge_commits_identical_chains() {
        crate::util::check::fault_scenario("leader-crash-mid-surge", 0xC2A54, |seed| {
            use std::collections::HashSet;
            let cfg = OrdererConfig {
                consensus_nodes: 5,
                batch_size: 5,
                // Throttle cutting so the surge is still in flight when the
                // fault fires at t=0.5s.
                min_block_interval: Duration::from_millis(25),
                consensus_net: crate::consensus::TransportConfig::lan(seed),
                consensus_faults: Some(FaultPlan::new(seed).at(0.5, Fault::CrashLeader)),
                ..OrdererConfig::default()
            };
            let (peers, orderer) = network(3, cfg);
            let rx = peers[2].subscribe("ch").unwrap();
            for nonce in 0..60 {
                orderer.submit(endorsed_envelope(&peers, nonce)).unwrap();
            }
            let mut valid: HashSet<crate::ledger::tx::TxId> = HashSet::new();
            let deadline = Instant::now() + Duration::from_secs(30);
            while valid.len() < 60 && Instant::now() < deadline {
                match rx.recv_timeout(Duration::from_secs(30)) {
                    // Epoch-change re-proposals may replay committed batches;
                    // those replays must verdict DuplicateTxId, never Valid.
                    Ok(ev) if ev.code == ValidationCode::Valid => {
                        assert!(valid.insert(ev.tx_id), "tx committed Valid twice");
                    }
                    Ok(ev) => assert_eq!(ev.code, ValidationCode::DuplicateTxId),
                    Err(_) => break,
                }
            }
            assert_eq!(valid.len(), 60, "every tx survives the leader crash");
            // The re-election is observable even if the surge finished first:
            // the dead leader stops heartbeating, so the survivors' election
            // timers fire regardless. Wait for the term to advance.
            let deadline = Instant::now() + Duration::from_secs(10);
            while orderer.consensus_stats().epoch < 2 && Instant::now() < deadline {
                thread::sleep(Duration::from_millis(10));
            }
            let stats = orderer.consensus_stats();
            assert!(stats.epoch >= 2, "survivors re-elected: {stats:?}");
            assert!(stats.epoch_changes >= 2, "crash forced a new election: {stats:?}");
            assert_eq!(stats.divergence, 0, "no replica disagreed on a slot");
            assert_eq!(stats.driver_lost(), 0, "transport accounted for every message");
            assert_eq!(orderer.bad_batches(), 0);
            let text = crate::telemetry::global().registry().render_prometheus();
            assert!(text.contains("scalesfl_consensus_commits_total"), "metrics exported");
            assert!(text.contains("protocol=\"raft\""));
            drop(orderer); // joins driver + committer: chains are final
            let chains: Vec<Vec<crate::crypto::Digest>> = peers
                .iter()
                .map(|p| {
                    let ch = p.channel("ch").unwrap();
                    let chain = ch.chain.lock().unwrap();
                    chain.verify().unwrap();
                    chain.iter().map(|b| b.hash()).collect()
                })
                .collect();
            assert!(!chains[0].is_empty());
            assert_eq!(chains[0], chains[1], "replica 1 diverged");
            assert_eq!(chains[0], chains[2], "replica 2 diverged");
            for p in &peers {
                assert_eq!(p.channel("ch").unwrap().scan("kv-k").len(), 60);
            }
        });
    }

    /// PBFT loses its primary before anything was ordered. The orderer must
    /// still make progress: the driver plays the PBFT client and shows the
    /// waiting batch to the backups, whose request timers then force the
    /// view change that installs a live primary.
    #[test]
    fn pbft_primary_crash_triggers_view_change_and_recovers() {
        crate::util::check::fault_scenario("pbft-primary-crash", 0x0DD5, |seed| {
            use std::collections::HashSet;
            let cfg = OrdererConfig {
                consensus: ConsensusKind::Pbft,
                consensus_nodes: 4,
                consensus_net: crate::consensus::TransportConfig::lan(seed),
                consensus_faults: Some(FaultPlan::new(seed).at(0.05, Fault::Crash(0))),
                ..OrdererConfig::default()
            };
            let (peers, orderer) = network(2, cfg);
            let rx = peers[1].subscribe("ch").unwrap();
            for nonce in 0..8 {
                orderer.submit(endorsed_envelope(&peers, nonce)).unwrap();
            }
            let mut valid: HashSet<crate::ledger::tx::TxId> = HashSet::new();
            let deadline = Instant::now() + Duration::from_secs(30);
            while valid.len() < 8 && Instant::now() < deadline {
                match rx.recv_timeout(Duration::from_secs(30)) {
                    Ok(ev) if ev.code == ValidationCode::Valid => {
                        valid.insert(ev.tx_id);
                    }
                    Ok(ev) => assert_eq!(ev.code, ValidationCode::DuplicateTxId),
                    Err(_) => break,
                }
            }
            assert_eq!(valid.len(), 8, "all txs commit after the view change");
            let stats = orderer.consensus_stats();
            assert!(stats.epoch >= 1, "view advanced past the dead primary: {stats:?}");
            assert_eq!(stats.divergence, 0);
            assert_eq!(stats.driver_lost(), 0);
            assert_eq!(orderer.bad_batches(), 0);
        });
    }

    /// A Byzantine primary equivocates: every backup receives a different
    /// forged pre-prepare for the same slot. No forged variant can gather a
    /// prepare quorum, the stall forces a view change, and the honest batch
    /// (carried in the backups' pending sets) commits under the new primary.
    /// Forged variants that ride along decode-fail (trailing bytes) and are
    /// counted as bad batches, never delivered.
    #[test]
    fn byzantine_equivocating_primary_is_contained() {
        crate::util::check::fault_scenario("pbft-equivocating-primary", 0xEB02, |seed| {
            use std::collections::HashSet;
            let cfg = OrdererConfig {
                consensus: ConsensusKind::Pbft,
                consensus_nodes: 4,
                consensus_net: crate::consensus::TransportConfig::lan(seed),
                consensus_faults: Some(FaultPlan::new(seed).at(0.0, Fault::Equivocate(0))),
                ..OrdererConfig::default()
            };
            let (peers, orderer) = network(2, cfg);
            let rx = peers[0].subscribe("ch").unwrap();
            for nonce in 0..5 {
                orderer.submit(endorsed_envelope(&peers, nonce)).unwrap();
            }
            let mut valid: HashSet<crate::ledger::tx::TxId> = HashSet::new();
            let deadline = Instant::now() + Duration::from_secs(30);
            while valid.len() < 5 && Instant::now() < deadline {
                match rx.recv_timeout(Duration::from_secs(30)) {
                    Ok(ev) if ev.code == ValidationCode::Valid => {
                        valid.insert(ev.tx_id);
                    }
                    Ok(ev) => assert_eq!(ev.code, ValidationCode::DuplicateTxId),
                    Err(_) => break,
                }
            }
            assert_eq!(valid.len(), 5, "honest batch survives the equivocator");
            let stats = orderer.consensus_stats();
            assert!(stats.epoch >= 1, "equivocator voted out via view change: {stats:?}");
            assert_eq!(stats.divergence, 0, "equivocation never splits committed state");
            assert_eq!(stats.driver_lost(), 0);
            assert!(
                orderer.bad_batches() >= 1,
                "forged pre-prepare variants surface as rejected batches"
            );
            drop(orderer);
            let chains: Vec<Vec<crate::crypto::Digest>> = peers
                .iter()
                .map(|p| {
                    let ch = p.channel("ch").unwrap();
                    let chain = ch.chain.lock().unwrap();
                    chain.verify().unwrap();
                    chain.iter().map(|b| b.hash()).collect()
                })
                .collect();
            assert_eq!(chains[0], chains[1], "peers diverged under equivocation");
        });
    }

    #[test]
    fn catalyst_lane_orders_ahead_of_queries() {
        // Large batch_size so a single timeout cut carries every pending tx
        // in one block; the catalyst envelope must lead it despite being
        // submitted last.
        let cfg = OrdererConfig {
            batch_size: 100,
            batch_timeout: Duration::from_millis(60),
            ..OrdererConfig::default()
        };
        let (peers, orderer) = network(2, cfg);
        let rx = peers[0].subscribe("ch").unwrap();
        for nonce in 0..3 {
            orderer.submit(endorsed_envelope(&peers, nonce)).unwrap();
        }
        let catalyst = endorsed_envelope_for(&peers, "catalyst", 50);
        let catalyst_id = catalyst.tx_id();
        orderer.submit(catalyst).unwrap();
        let first = rx.recv_timeout(Duration::from_secs(5)).expect("commit");
        assert_eq!(first.code, ValidationCode::Valid);
        assert_eq!(first.tx_id, catalyst_id, "catalyst tx should lead the block");
    }

    #[test]
    fn bounded_pool_sheds_overload_but_commits_admitted() {
        let mempool = MempoolRegistry::new(MempoolConfig {
            lane_capacity: 8,
            ..Default::default()
        });
        let cfg = OrdererConfig {
            batch_size: 4,
            batch_timeout: Duration::from_millis(20),
            // Throttle consensus so the burst below genuinely overflows.
            min_block_interval: Duration::from_millis(40),
            ..OrdererConfig::default()
        };
        let (peers, orderer) = network_with(2, cfg, Some(mempool));
        let rx = peers[0].subscribe("ch").unwrap();
        let mut admitted = 0u32;
        let mut shed = 0u32;
        for nonce in 0..40 {
            match orderer.submit(endorsed_envelope(&peers, nonce)) {
                Ok(()) => admitted += 1,
                Err(Reject::PoolFull) => shed += 1,
                Err(other) => panic!("unexpected reject: {other:?}"),
            }
        }
        assert!(shed > 0, "expected backpressure from the bounded pool");
        assert!(admitted >= 8, "burst should fill the lane");
        for _ in 0..admitted {
            let ev = rx.recv_timeout(Duration::from_secs(10)).expect("commit");
            assert_eq!(ev.code, ValidationCode::Valid);
        }
        let stats = orderer.mempool().snapshot();
        assert_eq!(stats.admitted as u32, admitted);
        assert_eq!(stats.pool_full as u32, shed);
        assert_eq!(stats.txs_ordered as u32, admitted);
        assert!(stats.depth_high_water <= 3 * 8, "queue stayed bounded");
    }

    #[test]
    fn cursor_does_not_alias_with_throttled_ticks() {
        // min_block_interval = 2 ticks: bandwidth frees up every other
        // tick. The old per-tick rotation advanced by 2 between serves
        // (even), so with 2 channels the same one led every opportunity.
        // The cursor only moves on service, and throttled ticks leave it
        // untouched, so service alternates.
        let chans = vec!["cha".to_string(), "chb".to_string()];
        let mut c = ChannelCursor::default();
        let mut served = Vec::new();
        for tick in 0..8 {
            let first = c.order(&chans)[0];
            if tick % 2 == 0 {
                served.push(first);
                c.served(&chans[first]);
            }
            // Throttled tick: no cut, cursor untouched.
        }
        assert_eq!(served, vec![0, 1, 0, 1]);
    }

    #[test]
    fn cursor_tracks_names_across_channel_set_growth() {
        let chans = vec!["cha".to_string(), "chb".to_string()];
        let mut c = ChannelCursor::default();
        c.served(&chans[1]); // "chb" just got a block
        // A lazily created channel sorting ahead of the others must not
        // shift the rotation: after "chb" the wrap goes to "aaa".
        let grown =
            vec!["aaa".to_string(), "cha".to_string(), "chb".to_string()];
        assert_eq!(c.order(&grown), vec![0, 1, 2]);
        c.served("aaa");
        assert_eq!(c.order(&grown)[0], 1, "cha is aaa's sorted successor");
        // A served channel disappearing (pool drained away) is harmless.
        c.served("chb");
        assert_eq!(c.order(&chans[..1]), vec![0]);
        assert!(c.order(&[]).is_empty());
    }

    #[test]
    fn throttled_orderer_round_robins_channels() {
        // Two saturated channels behind one block per 30 ms of consensus
        // bandwidth: their drains must interleave, finishing within a few
        // block intervals of each other instead of serially.
        let ca = CertificateAuthority::new();
        let mut rng = Prng::new(11);
        let peers: Vec<Arc<Peer>> = (0..2)
            .map(|i| {
                let cred = ca.enroll(MemberId::new(format!("org{i}.peer")), &mut rng);
                Peer::new(cred, ca.clone())
            })
            .collect();
        let members: Vec<MemberId> = peers.iter().map(|p| p.member.clone()).collect();
        for p in &peers {
            for ch in ["cha", "chb"] {
                p.join_channel(ch, EndorsementPolicy::MajorityOf(members.clone()));
                p.install_chaincode(ch, Arc::new(PutAs("kv"))).unwrap();
            }
        }
        // Preload both pools (6 full batches each) before the orderer runs.
        let mempool = MempoolRegistry::new(MempoolConfig::default());
        let per_channel = 24;
        for ch in ["cha", "chb"] {
            for nonce in 0..per_channel {
                mempool.submit(endorsed_envelope_on(&peers, ch, "kv", nonce)).unwrap();
            }
        }
        let rx_a = peers[0].subscribe("cha").unwrap();
        let rx_b = peers[0].subscribe("chb").unwrap();
        let min_interval = Duration::from_millis(30);
        let orderer = OrderingService::start_with_mempool(
            OrdererConfig {
                batch_size: 4,
                batch_timeout: Duration::from_millis(5),
                min_block_interval: min_interval,
                tick: Duration::from_millis(1),
                ..Default::default()
            },
            peers.clone(),
            42,
            mempool,
        );
        let started = Instant::now();
        let (done_a, done_b) = thread::scope(|s| {
            let drain = |rx: crate::fabric::peer::Subscription| {
                move || {
                    for _ in 0..per_channel {
                        rx.recv_timeout(Duration::from_secs(20)).expect("commit");
                    }
                    started.elapsed()
                }
            };
            let ha = s.spawn(drain(rx_a));
            let hb = s.spawn(drain(rx_b));
            (ha.join().unwrap(), hb.join().unwrap())
        });
        drop(orderer);
        let gap = if done_a > done_b { done_a - done_b } else { done_b - done_a };
        // Fair interleaving finishes both within ~1 interval; the per-tick
        // rotation bug drained one channel completely first (~6 intervals).
        assert!(gap <= 3 * min_interval, "unfair channel service: gap {gap:?}");
    }

    #[test]
    fn corrupt_committed_payload_is_counted_not_lost() {
        let (tx, rx) = mpsc::channel();
        let bad = AtomicU64::new(0);
        let good = wire::encode_batch("ch", &[]);
        // Valid payload: delivered, nothing counted.
        assert!(deliver_committed(&good, &tx, &bad));
        assert_eq!(rx.try_recv().unwrap().0, "ch");
        assert_eq!(bad.load(Ordering::Relaxed), 0);
        // Truncated payload: counted and skipped, but the driver keeps
        // running (true) — one poisoned batch must not stall the pipeline.
        assert!(deliver_committed(&good[..good.len() - 1], &tx, &bad));
        assert!(rx.try_recv().is_err());
        assert_eq!(bad.load(Ordering::Relaxed), 1);
        // A valid payload with the committer gone means shutdown.
        drop(rx);
        assert!(!deliver_committed(&good, &tx, &bad));
        assert_eq!(bad.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn durable_orderer_attaches_stores_and_persists_commits() {
        use crate::ledger::store::{DurabilityMode, LedgerConfig};
        use crate::util::tempdir::TempDir;

        let dir = TempDir::new("orderer-ledger");
        let mut lcfg = LedgerConfig::new(dir.path().to_path_buf());
        lcfg.durability = DurabilityMode::Off;
        let cfg = OrdererConfig { ledger: Some(lcfg), ..OrdererConfig::default() };
        let (peers, orderer) = network(2, cfg);
        let rx = peers[1].subscribe("ch").unwrap();
        for nonce in 0..5 {
            orderer.submit(endorsed_envelope(&peers, nonce)).unwrap();
        }
        for _ in 0..5 {
            rx.recv_timeout(Duration::from_secs(10)).expect("commit");
        }
        assert_eq!(orderer.bad_batches(), 0);
        drop(orderer); // drains the committer: every replica fully applied
        for p in &peers {
            let ch = p.channel("ch").unwrap();
            let store = ch.store().expect("store attached at startup");
            assert!(ch.height() > 0);
            assert_eq!(store.height(), ch.height());
            assert_eq!(store.stats().blocks_appended, ch.height());
        }
    }

    #[test]
    fn duplicate_submission_rejected_at_ingress() {
        let (peers, orderer) = network(2, OrdererConfig::default());
        let env = endorsed_envelope(&peers, 7);
        orderer.submit(env.clone()).unwrap();
        assert_eq!(orderer.submit(env), Err(Reject::Duplicate));
    }

    /// Two-channel topology with the cross-shard relay enabled.
    fn relay_network(
        cfg: OrdererConfig,
    ) -> (Vec<Arc<Peer>>, Arc<OrderingService>) {
        let ca = CertificateAuthority::new();
        let mut rng = Prng::new(23);
        let peers: Vec<Arc<Peer>> = (0..2)
            .map(|i| {
                let cred = ca.enroll(MemberId::new(format!("org{i}.peer")), &mut rng);
                Peer::new(cred, ca.clone())
            })
            .collect();
        let members: Vec<MemberId> = peers.iter().map(|p| p.member.clone()).collect();
        for p in &peers {
            for ch in ["cha", "chb", "mainchain"] {
                p.join_channel(ch, EndorsementPolicy::MajorityOf(members.clone()));
                p.install_chaincode(ch, Arc::new(PutAs("kv"))).unwrap();
                p.install_chaincode(ch, Arc::new(PutAs("catalyst"))).unwrap();
            }
        }
        let orderer = OrderingService::start(cfg, peers.clone(), 23);
        (peers, orderer)
    }

    fn relay_cfg() -> OrdererConfig {
        OrdererConfig {
            batch_timeout: Duration::from_millis(10),
            tick: Duration::from_millis(1),
            relay: Some(crate::mempool::RelayConfig {
                base_latency: Duration::from_millis(5),
                latency_spread: Duration::from_millis(5),
                jitter: Duration::from_millis(1),
                seed: 3,
            }),
            ..OrdererConfig::default()
        }
    }

    /// The end-to-end acceptance path: an envelope submitted at the wrong
    /// shard's ingress hops home over the relay (paying its link latency)
    /// and commits exactly once on its home channel.
    #[test]
    fn misrouted_submission_relays_home_and_commits_once() {
        let (peers, orderer) = relay_network(relay_cfg());
        // Subscribe on the last replica the committer serves, so the event
        // implies every earlier replica already applied the block.
        let rx = peers[1].subscribe("cha").unwrap();
        let env = endorsed_envelope_on(&peers, "cha", "kv", 1);
        let tx_id = env.tx_id();
        // Enters at chb's pool; its home is cha.
        orderer.submit_from(Some("chb"), env).unwrap();
        let ev = rx.recv_timeout(Duration::from_secs(10)).expect("relayed commit");
        assert_eq!(ev.tx_id, tx_id);
        assert_eq!(ev.code, ValidationCode::Valid);
        // Forwarded once, delivered once, committed once — on cha only.
        let relay = orderer.relay().expect("relay configured");
        let snap = relay.snapshot();
        assert_eq!(snap.forwarded, 1);
        assert_eq!(snap.delivered, 1);
        assert_eq!(snap.dropped + snap.deduped, 0);
        assert!(snap.mean_hop_latency_s() >= 0.004, "{}", snap.mean_hop_latency_s());
        let stats = orderer.mempool().snapshot();
        assert_eq!(stats.forwarded, 1);
        assert_eq!(stats.txs_ordered, 1);
        for p in &peers {
            assert_eq!(p.channel("cha").unwrap().scan("kv-k").len(), 1);
            assert_eq!(p.channel("chb").unwrap().height(), 0);
        }
    }

    /// A shard-produced catalyst/checkpoint transaction entering at the
    /// shard's ingress is relayed to the mainchain channel as a
    /// first-class cross-shard message and commits there exactly once.
    #[test]
    fn shard_checkpoint_relays_to_mainchain() {
        let (peers, orderer) = relay_network(relay_cfg());
        let rx = peers[1].subscribe("mainchain").unwrap();
        let env = endorsed_envelope_on(&peers, "mainchain", "catalyst", 9);
        let tx_id = env.tx_id();
        orderer.submit_from(Some("cha"), env).unwrap();
        let ev = rx.recv_timeout(Duration::from_secs(10)).expect("checkpoint commit");
        assert_eq!(ev.tx_id, tx_id);
        assert_eq!(ev.code, ValidationCode::Valid);
        assert_eq!(&*ev.channel, "mainchain");
        let ingress_pool = orderer.mempool().get("cha").expect("ingress pool exists");
        assert_eq!(ingress_pool.stats().forwarded, 1);
        for p in &peers {
            assert_eq!(p.channel("mainchain").unwrap().scan("catalyst-k").len(), 1);
        }
    }

    /// The same transaction gossiped through two ingress pools commits
    /// exactly once (home-pool dedup), and both routes account for it.
    #[test]
    fn gossiped_duplicate_commits_exactly_once() {
        let (peers, orderer) = relay_network(relay_cfg());
        let rx = peers[1].subscribe("cha").unwrap();
        let env = endorsed_envelope_on(&peers, "cha", "kv", 4);
        orderer.submit_from(Some("chb"), env.clone()).unwrap();
        orderer.submit_from(Some("mainchain"), env).unwrap();
        let ev = rx.recv_timeout(Duration::from_secs(10)).expect("commit");
        assert_eq!(ev.code, ValidationCode::Valid);
        // No second commit event for the deduped copy.
        assert!(rx.recv_timeout(Duration::from_millis(300)).is_err());
        let snap = orderer.relay().unwrap().snapshot();
        assert_eq!(snap.forwarded, 2);
        assert_eq!(snap.delivered, 1);
        assert_eq!(snap.deduped, 1);
        assert_eq!(snap.dropped, 0);
        for p in &peers {
            assert_eq!(p.channel("cha").unwrap().scan("kv-k").len(), 1);
        }
    }
}
