//! The ordering service: batches endorsed envelopes into blocks through a
//! Raft cluster (the paper's orderer) and delivers committed blocks to every
//! peer on the batch's channel.
//!
//! One driver thread owns the whole consensus group (sans-io Raft nodes with
//! in-memory message exchange — the paper likewise ran a single ordering
//! process) plus the batching state: a block is cut when `batch_size`
//! envelopes are pending or `batch_timeout` elapsed since the first.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::thread;
use std::time::{Duration, Instant};

use crate::consensus::pbft::{Pbft, PbftConfig};
use crate::consensus::raft::{Raft, RaftConfig};
use crate::consensus::ConsensusNode;
use crate::ledger::tx::Envelope;
use crate::util::prng::Prng;

use super::peer::Peer;
use super::wire;

/// Which consensus protocol orders blocks (the paper's §3.2 pluggable
/// consensus: Raft for trusted/small shards, PBFT for byzantine settings).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ConsensusKind {
    Raft,
    Pbft,
}

/// Ordering service configuration.
#[derive(Clone, Debug)]
pub struct OrdererConfig {
    /// Envelopes per block before a cut is forced.
    pub batch_size: usize,
    /// Max time the first pending envelope waits before a cut.
    pub batch_timeout: Duration,
    /// Consensus cluster size (1 = the paper's single orderer).
    pub consensus_nodes: usize,
    /// Ordering protocol.
    pub consensus: ConsensusKind,
    /// Driver loop granularity.
    pub tick: Duration,
}

impl Default for OrdererConfig {
    fn default() -> Self {
        OrdererConfig {
            batch_size: 10,
            batch_timeout: Duration::from_millis(100),
            consensus_nodes: 1,
            consensus: ConsensusKind::Raft,
            tick: Duration::from_millis(2),
        }
    }
}

enum Input {
    Submit(Envelope),
    Shutdown,
}

/// Handle to the running ordering service.
pub struct OrderingService {
    tx: mpsc::Sender<Input>,
    handle: Option<thread::JoinHandle<()>>,
    blocks_cut: Arc<AtomicU64>,
}

impl OrderingService {
    /// Start the orderer; committed blocks are delivered synchronously to
    /// every peer in `peers` that joined the batch's channel.
    pub fn start(cfg: OrdererConfig, peers: Vec<Arc<Peer>>, seed: u64) -> Arc<OrderingService> {
        let (tx, rx) = mpsc::channel::<Input>();
        let blocks_cut = Arc::new(AtomicU64::new(0));
        let counter = Arc::clone(&blocks_cut);
        let handle = thread::Builder::new()
            .name("orderer".into())
            .spawn(move || {
                let n = cfg.consensus_nodes.max(1);
                let mut rng = Prng::new(seed);
                match cfg.consensus {
                    ConsensusKind::Raft => {
                        let nodes: Vec<Raft> = (0..n)
                            .map(|i| Raft::new(i, n, RaftConfig::default(), rng.fork(i as u64)))
                            .collect();
                        driver(cfg, peers, rx, counter, nodes)
                    }
                    ConsensusKind::Pbft => {
                        let nodes: Vec<Pbft> =
                            (0..n).map(|i| Pbft::new(i, n, PbftConfig::default())).collect();
                        driver(cfg, peers, rx, counter, nodes)
                    }
                }
            })
            .expect("spawn orderer");
        Arc::new(OrderingService { tx, handle: Some(handle), blocks_cut })
    }

    /// Submit an endorsed envelope for ordering.
    pub fn submit(&self, env: Envelope) -> Result<(), String> {
        self.tx.send(Input::Submit(env)).map_err(|_| "orderer stopped".to_string())
    }

    pub fn blocks_cut(&self) -> u64 {
        self.blocks_cut.load(Ordering::Relaxed)
    }
}

impl Drop for OrderingService {
    fn drop(&mut self) {
        let _ = self.tx.send(Input::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn driver<C: ConsensusNode>(
    cfg: OrdererConfig,
    peers: Vec<Arc<Peer>>,
    rx: mpsc::Receiver<Input>,
    blocks_cut: Arc<AtomicU64>,
    mut nodes: Vec<C>,
) {
    // Pending envelopes per channel + arrival time of the oldest.
    let mut pending: HashMap<String, (Vec<Envelope>, Instant)> = HashMap::new();
    let start = Instant::now();
    let mut delivered_seq = 0u64;

    loop {
        // Drain inputs without blocking longer than one tick.
        let deadline = Instant::now() + cfg.tick;
        loop {
            let timeout = deadline.saturating_duration_since(Instant::now());
            match rx.recv_timeout(timeout) {
                Ok(Input::Submit(env)) => {
                    let channel = env.proposal.channel.clone();
                    pending
                        .entry(channel)
                        .or_insert_with(|| (Vec::new(), Instant::now()))
                        .0
                        .push(env);
                }
                Ok(Input::Shutdown) => return,
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => return,
            }
        }

        let now = start.elapsed().as_secs_f64();
        // Consensus housekeeping: ticks + instant message exchange.
        let mut inbox: Vec<(usize, usize, C::Msg)> = Vec::new();
        for node in nodes.iter_mut() {
            for (to, m) in node.tick(now) {
                inbox.push((node.node_id(), to, m));
            }
        }
        // Settle the exchange (bounded rounds to avoid spinning).
        for _ in 0..8 {
            if inbox.is_empty() {
                break;
            }
            let mut next = Vec::new();
            for (from, to, m) in inbox.drain(..) {
                for (dest, out) in nodes[to].handle(from, m, now) {
                    next.push((to, dest, out));
                }
            }
            inbox = next;
        }

        // Cut blocks where due and propose through the leader.
        let leader = nodes.iter().position(|nd| nd.is_leader());
        if let Some(l) = leader {
            let due: Vec<String> = pending
                .iter()
                .filter(|(_, (envs, since))| {
                    !envs.is_empty()
                        && (envs.len() >= cfg.batch_size || since.elapsed() >= cfg.batch_timeout)
                })
                .map(|(ch, _)| ch.clone())
                .collect();
            for ch in due {
                let (mut envs, _) = pending.remove(&ch).unwrap();
                // Respect batch_size per block; leftover re-queues.
                let rest = if envs.len() > cfg.batch_size {
                    envs.split_off(cfg.batch_size)
                } else {
                    Vec::new()
                };
                if !rest.is_empty() {
                    pending.insert(ch.clone(), (rest, Instant::now()));
                }
                let payload = wire::encode_batch(&ch, &envs);
                if nodes[l].propose(payload, now).is_err() {
                    // Leadership moved; re-queue and retry next tick.
                    pending.entry(ch).or_insert_with(|| (Vec::new(), Instant::now())).0.extend(envs);
                } else {
                    // Protocols that broadcast at proposal time (PBFT).
                    for (to, m) in nodes[l].take_outbound() {
                        inbox.push((l, to, m));
                    }
                    for _ in 0..8 {
                        if inbox.is_empty() {
                            break;
                        }
                        let mut next = Vec::new();
                        for (from, to, m) in inbox.drain(..) {
                            for (dest, out) in nodes[to].handle(from, m, now) {
                                next.push((to, dest, out));
                            }
                        }
                        inbox = next;
                    }
                }
            }
        }

        // Deliver committed batches (node 0's stream; all nodes agree).
        for c in nodes[0].take_committed() {
            debug_assert_eq!(c.seq, delivered_seq + 1);
            delivered_seq = c.seq;
            match wire::decode_batch(&c.data) {
                Ok((channel, envs)) => {
                    blocks_cut.fetch_add(1, Ordering::Relaxed);
                    for p in &peers {
                        if p.channel(&channel).is_some() {
                            if let Err(e) = p.commit_batch(&channel, envs.clone()) {
                                eprintln!("orderer: commit failed on {}: {e}", p.member);
                            }
                        }
                    }
                }
                Err(e) => eprintln!("orderer: bad batch payload: {e}"),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::msp::{CertificateAuthority, MemberId};
    use crate::fabric::chaincode::{Chaincode, TxContext};
    use crate::fabric::endorsement::EndorsementPolicy;
    use crate::ledger::block::ValidationCode;
    use crate::ledger::tx::Proposal;

    struct PutCc;
    impl Chaincode for PutCc {
        fn name(&self) -> &str {
            "kv"
        }
        fn invoke(
            &self,
            ctx: &mut TxContext<'_>,
            _f: &str,
            args: &[String],
        ) -> Result<Vec<u8>, String> {
            ctx.put(&args[0], args[1].as_bytes().to_vec());
            Ok(vec![])
        }
    }

    fn network(n_peers: usize, cfg: OrdererConfig) -> (Vec<Arc<Peer>>, Arc<OrderingService>) {
        let ca = CertificateAuthority::new();
        let mut rng = Prng::new(1);
        let peers: Vec<Arc<Peer>> = (0..n_peers)
            .map(|i| {
                let cred = ca.enroll(MemberId::new(format!("org{i}.peer")), &mut rng);
                Peer::new(cred, ca.clone())
            })
            .collect();
        let members: Vec<MemberId> = peers.iter().map(|p| p.member.clone()).collect();
        for p in &peers {
            p.join_channel("ch", EndorsementPolicy::MajorityOf(members.clone()));
            p.install_chaincode("ch", Arc::new(PutCc)).unwrap();
        }
        let orderer = OrderingService::start(cfg, peers.clone(), 42);
        (peers, orderer)
    }

    fn endorsed_envelope(peers: &[Arc<Peer>], nonce: u64) -> Envelope {
        let prop = Proposal {
            channel: "ch".into(),
            chaincode: "kv".into(),
            function: "Put".into(),
            args: vec![format!("k{nonce}"), "v".into()],
            creator: MemberId::new("client"),
            nonce,
        };
        let mut endorsements = Vec::new();
        let mut rw = None;
        for p in peers {
            let (r, e, _) = p.endorse(&prop).unwrap();
            rw = Some(r);
            endorsements.push(e);
        }
        Envelope { proposal: prop, rw_set: rw.unwrap(), endorsements }
    }

    #[test]
    fn orders_and_commits_across_peers() {
        let (peers, orderer) = network(3, OrdererConfig::default());
        let rx = peers[2].subscribe("ch").unwrap();
        for nonce in 0..25 {
            orderer.submit(endorsed_envelope(&peers, nonce)).unwrap();
        }
        let mut got = 0;
        while got < 25 {
            let ev = rx.recv_timeout(Duration::from_secs(10)).expect("commit event");
            assert_eq!(ev.code, ValidationCode::Valid);
            got += 1;
        }
        for p in &peers {
            let ch = p.channel("ch").unwrap();
            assert_eq!(ch.scan("k").len(), 25);
            ch.chain.lock().unwrap().verify().unwrap();
        }
        assert!(orderer.blocks_cut() >= 3); // batch_size 10 -> >= 3 blocks
    }

    #[test]
    fn batch_timeout_cuts_partial_blocks() {
        let cfg = OrdererConfig {
            batch_size: 100,
            batch_timeout: Duration::from_millis(30),
            ..OrdererConfig::default()
        };
        let (peers, orderer) = network(2, cfg);
        let rx = peers[0].subscribe("ch").unwrap();
        orderer.submit(endorsed_envelope(&peers, 1)).unwrap();
        let ev = rx.recv_timeout(Duration::from_secs(5)).expect("timeout cut");
        assert_eq!(ev.code, ValidationCode::Valid);
    }

    #[test]
    fn pbft_orderer_works() {
        let cfg = OrdererConfig {
            consensus: ConsensusKind::Pbft,
            consensus_nodes: 4,
            ..OrdererConfig::default()
        };
        let (peers, orderer) = network(2, cfg);
        let rx = peers[0].subscribe("ch").unwrap();
        for nonce in 0..8 {
            orderer.submit(endorsed_envelope(&peers, nonce)).unwrap();
        }
        for _ in 0..8 {
            let ev = rx.recv_timeout(Duration::from_secs(10)).expect("commit");
            assert_eq!(ev.code, ValidationCode::Valid);
        }
    }

    #[test]
    fn multi_node_raft_orderer_works() {
        let cfg = OrdererConfig { consensus_nodes: 3, ..OrdererConfig::default() };
        let (peers, orderer) = network(2, cfg);
        let rx = peers[1].subscribe("ch").unwrap();
        for nonce in 0..5 {
            orderer.submit(endorsed_envelope(&peers, nonce)).unwrap();
        }
        for _ in 0..5 {
            let ev = rx.recv_timeout(Duration::from_secs(10)).expect("commit");
            assert_eq!(ev.code, ValidationCode::Valid);
        }
    }
}
