//! Binary wire format for envelopes and block payloads — what the ordering
//! service replicates through consensus, and (block-framed) what the
//! durable ledger (`crate::ledger::store`) persists per record.
//!
//! The per-envelope codec lives in `crate::ledger::envelope` (re-exported
//! here) because the canonical encoding *is* the in-memory representation:
//! a [`SharedEnvelope`] carries its wire bytes, so batch and block
//! serialization splice those buffers (`Writer::raw`) instead of
//! re-encoding field by field, and decoding a payload yields
//! `SharedEnvelope`s whose buffers are sub-slices copied straight out of
//! the payload with the decoded form pre-seeded.

use crate::crypto::Digest;
use crate::ledger::block::{Block, BlockHeader, ValidationCode};
use crate::ledger::codec::{Reader, Writer};
use crate::ledger::envelope::SharedEnvelope;

pub use crate::ledger::envelope::{decode_envelope, encode_envelope};

/// Decode one envelope out of a larger payload, carving its canonical
/// byte span into a fresh [`SharedEnvelope`] (decoded form pre-seeded, so
/// nothing downstream re-parses).
fn decode_shared(r: &mut Reader<'_>) -> Result<SharedEnvelope, String> {
    let start = r.pos();
    let env = decode_envelope(r)?;
    let bytes = r.underlying()[start..r.pos()].to_vec();
    Ok(SharedEnvelope::from_wire_decoded(bytes, env))
}

/// A consensus payload: one cut batch for one channel. Envelope buffers
/// are spliced, not re-encoded.
pub fn encode_batch(channel: &str, envs: &[SharedEnvelope]) -> Vec<u8> {
    let mut w = Writer::new();
    w.str(channel).u32(envs.len() as u32);
    for e in envs {
        e.write_to(&mut w);
    }
    w.finish()
}

/// Decode a consensus payload into (channel, envelopes).
pub fn decode_batch(buf: &[u8]) -> Result<(String, Vec<SharedEnvelope>), String> {
    let mut r = Reader::new(buf);
    let channel = r.str()?;
    let n = r.u32()? as usize;
    let mut envs = Vec::with_capacity(n.min(4096));
    for _ in 0..n {
        envs.push(decode_shared(&mut r)?);
    }
    if !r.done() {
        return Err("trailing bytes in batch".into());
    }
    Ok((channel, envs))
}

fn code_to_u8(c: ValidationCode) -> u8 {
    match c {
        ValidationCode::Valid => 0,
        ValidationCode::MvccConflict => 1,
        ValidationCode::EndorsementPolicyFailure => 2,
        ValidationCode::DuplicateTxId => 3,
    }
}

fn code_from_u8(b: u8) -> Result<ValidationCode, String> {
    match b {
        0 => Ok(ValidationCode::Valid),
        1 => Ok(ValidationCode::MvccConflict),
        2 => Ok(ValidationCode::EndorsementPolicyFailure),
        3 => Ok(ValidationCode::DuplicateTxId),
        other => Err(format!("unknown validation code {other}")),
    }
}

fn digest(r: &mut Reader<'_>) -> Result<Digest, String> {
    let b: [u8; 32] =
        r.bytes()?.try_into().map_err(|_| "bad digest length".to_string())?;
    Ok(Digest(b))
}

/// Serialize a committed block: header fields, ordered envelopes (spliced
/// canonical buffers — the single copy into the ledger store), and the
/// commit-time validation codes (one byte per tx). The header digests are
/// stored as written — not recomputed on decode — so a tampered payload
/// still fails `Block::verify_data_hash` after a roundtrip.
pub fn encode_block(b: &Block, w: &mut Writer) {
    w.u64(b.header.number);
    w.bytes(&b.header.prev_hash.0);
    w.bytes(&b.header.data_hash.0);
    w.u32(b.txs.len() as u32);
    for e in &b.txs {
        e.write_to(w);
    }
    w.u32(b.validation.len() as u32);
    for c in &b.validation {
        w.u8(code_to_u8(*c));
    }
}

/// Deserialize one block (inverse of [`encode_block`]).
pub fn decode_block(r: &mut Reader<'_>) -> Result<Block, String> {
    let number = r.u64()?;
    let prev_hash = digest(r)?;
    let data_hash = digest(r)?;
    let ntxs = r.u32()? as usize;
    let mut txs = Vec::with_capacity(ntxs.min(4096));
    for _ in 0..ntxs {
        txs.push(decode_shared(r)?);
    }
    let ncodes = r.u32()? as usize;
    if ncodes != ntxs {
        return Err(format!("{ncodes} validation codes for {ntxs} txs"));
    }
    let mut validation = Vec::with_capacity(ncodes);
    for _ in 0..ncodes {
        validation.push(code_from_u8(r.u8()?)?);
    }
    Ok(Block { header: BlockHeader { number, prev_hash, data_hash }, txs, validation })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::msp::{MemberId, Signature};
    use crate::ledger::state::Version;
    use crate::ledger::tx::{Endorsement, Envelope, Proposal, RwSet};
    use crate::util::check::check;
    use crate::util::prng::Prng;

    fn random_envelope(rng: &mut Prng) -> Envelope {
        let nargs = rng.below(4);
        Envelope {
            proposal: Proposal {
                channel: format!("shard{}", rng.below(8)),
                chaincode: "models".into(),
                function: "CreateModelUpdate".into(),
                args: (0..nargs).map(|i| format!("arg{i}-{}", rng.next_u64())).collect(),
                creator: MemberId::new(format!("org{}.client", rng.below(8))),
                nonce: rng.next_u64(),
            },
            rw_set: RwSet {
                reads: (0..rng.below(4))
                    .map(|i| {
                        let ver = if rng.below(2) == 0 {
                            None
                        } else {
                            Some(Version { block: rng.next_u64() % 100, tx: rng.below(10) as u32 })
                        };
                        (format!("rk{i}"), ver)
                    })
                    .collect(),
                writes: (0..rng.below(4))
                    .map(|i| {
                        let val = if rng.below(4) == 0 {
                            None
                        } else {
                            Some(rng.next_u64().to_le_bytes().to_vec())
                        };
                        (format!("wk{i}"), val)
                    })
                    .collect(),
            },
            endorsements: (0..rng.below(4))
                .map(|i| {
                    let mut sig = [0u8; 32];
                    for c in sig.chunks_mut(8) {
                        c.copy_from_slice(&rng.next_u64().to_le_bytes()[..c.len()]);
                    }
                    Endorsement {
                        endorser: MemberId::new(format!("org{i}.peer")),
                        signature: Signature(sig),
                    }
                })
                .collect(),
        }
    }

    #[test]
    fn property_envelope_roundtrip() {
        check("envelope-roundtrip", 40, |rng| {
            let env = random_envelope(rng);
            let mut w = Writer::new();
            encode_envelope(&env, &mut w);
            let buf = w.finish();
            let mut r = Reader::new(&buf);
            let back = decode_envelope(&mut r).unwrap();
            assert_eq!(back, env);
            assert!(r.done());
        });
    }

    #[test]
    fn batch_roundtrip_preserves_order() {
        let mut rng = Prng::new(5);
        let envs: Vec<SharedEnvelope> =
            (0..7).map(|_| random_envelope(&mut rng).into()).collect();
        let buf = encode_batch("shard3", &envs);
        let (ch, back) = decode_batch(&buf).unwrap();
        assert_eq!(ch, "shard3");
        assert_eq!(back, envs);
        // Decoded envelopes carry the exact same canonical bytes.
        for (a, b) in back.iter().zip(&envs) {
            assert_eq!(a.as_bytes(), b.as_bytes());
            assert_eq!(a.envelope(), b.envelope());
        }
    }

    fn random_block(rng: &mut Prng, number: u64) -> Block {
        let txs: Vec<Envelope> = (0..1 + rng.below(4)).map(|_| random_envelope(rng)).collect();
        let mut b = Block::new(number, Digest([rng.below(256) as u8; 32]), txs);
        b.validation = (0..b.txs.len())
            .map(|_| match rng.below(4) {
                0 => ValidationCode::Valid,
                1 => ValidationCode::MvccConflict,
                2 => ValidationCode::EndorsementPolicyFailure,
                _ => ValidationCode::DuplicateTxId,
            })
            .collect();
        b
    }

    #[test]
    fn property_block_roundtrip() {
        check("block-roundtrip", 32, |rng| {
            let b = random_block(rng, rng.next_u64() % 1000);
            let mut w = Writer::new();
            encode_block(&b, &mut w);
            let buf = w.finish();
            let mut r = Reader::new(&buf);
            let back = decode_block(&mut r).unwrap();
            assert!(r.done());
            assert_eq!(back, b);
            assert_eq!(back.hash(), b.hash());
            assert!(back.verify_data_hash());
        });
    }

    #[test]
    fn block_decode_rejects_tamper_and_truncation() {
        let mut rng = Prng::new(9);
        let b = random_block(&mut rng, 3);
        let mut w = Writer::new();
        encode_block(&b, &mut w);
        let buf = w.finish();
        // Truncation at any point errors instead of panicking.
        for cut in [0, 1, buf.len() / 2, buf.len() - 1] {
            assert!(decode_block(&mut Reader::new(&buf[..cut])).is_err(), "cut at {cut}");
        }
        // A flipped payload byte either fails to decode or decodes to a
        // block whose stored data hash no longer matches the envelopes —
        // the tamper check moves to `verify_data_hash`, exactly as for an
        // in-memory block.
        let mut flipped = buf.clone();
        // Header is 80 bytes (number + 2 length-prefixed digests); byte 85
        // sits inside the first envelope's payload.
        flipped[85] ^= 0xFF;
        if let Ok(back) = decode_block(&mut Reader::new(&flipped)) {
            assert!(!back.verify_data_hash());
        }
        // An unknown validation code errors.
        let mut bad_code = buf;
        let last = bad_code.len() - 1;
        bad_code[last] = 99;
        assert!(decode_block(&mut Reader::new(&bad_code)).is_err());
    }

    #[test]
    fn corrupt_batch_errors() {
        let mut rng = Prng::new(6);
        let buf = encode_batch("c", &[random_envelope(&mut rng).into()]);
        assert!(decode_batch(&buf[..buf.len() - 2]).is_err());
        let mut extra = buf.clone();
        extra.push(0);
        assert!(decode_batch(&extra).is_err());
    }
}
