//! Binary wire format for envelopes, block payloads, and — since the
//! multi-process split — the request/response/event frames that fabric
//! processes exchange over TCP/UDS sockets.
//!
//! The per-envelope codec lives in `crate::ledger::envelope` (re-exported
//! here) because the canonical encoding *is* the in-memory representation:
//! a [`SharedEnvelope`] carries its wire bytes, so batch, block, and frame
//! serialization splice those buffers (`Writer::raw`) instead of
//! re-encoding field by field, and decoding a payload yields
//! `SharedEnvelope`s whose buffers are sub-slices copied straight out of
//! the payload with the decoded form pre-seeded.
//!
//! # Process topology
//!
//! `scalesfl node orderer` hosts an ordering service plus its endorsing
//! peers for a set of channels; `scalesfl node gateway` fronts one or more
//! orderer processes and relays each client connection to the upstream
//! that owns the requested channel. A remote client
//! ([`crate::network::client::RemoteGateway`]) connects to either, sends
//! [`Request`] frames and receives correlated [`Response`] frames, while
//! [`Event`] frames stream back asynchronously on the same connection as
//! transactions commit — which is what lets the client library rebuild the
//! in-process `SubmitHandle`/`CommitWaiter` semantics across a socket.
//!
//! # Frame grammar
//!
//! Every frame travels length-prefixed by the transport
//! ([`crate::network::transport`]); the payload grammar uses the codec's
//! little-endian primitives (`bytes` = u32 len + raw, `str` = UTF-8
//! `bytes`, `bytes32` = `bytes` whose length must be 32):
//!
//! ```text
//! frame    = 0x00 request | 0x01 response | 0x02 event
//! request  = 0x00 id:u64 proposal                              ; Endorse
//!          | 0x01 id:u64 envelope:bytes                        ; Submit
//!          | 0x02 id:u64 channel:str                           ; Status
//! response = 0x00 id:u64 envelope:bytes                        ; Endorsed
//!          | 0x01 id:u64 tx_id:bytes32                         ; Accepted
//!          | 0x02 id:u64 reject:u8                             ; Rejected
//!          | 0x03 id:u64 reason:str                            ; Failed
//!          | 0x04 id:u64 height:u64 tip:bytes32 root:bytes32   ; Status
//! event    = 0x00 channel:str tx_id:bytes32 block:u64 code:u8  ; Committed
//!          | 0x01 channel:str tx_id:bytes32 reject:u8          ; Dropped
//! ```
//!
//! Decoders here never trust a length or count prefix: every one is
//! validated against the bytes actually remaining (`Reader::count`, and
//! bounds-checked reads) before any allocation is sized from it, and all
//! errors are the typed [`WireError`] — [`WireError::Truncated`] for torn
//! input a transport may retry, [`WireError::Malformed`] for structurally
//! invalid frames that warrant closing the connection.

use crate::crypto::Digest;
use crate::ledger::block::{Block, BlockHeader, ValidationCode};
use crate::ledger::codec::{Reader, Writer};
use crate::ledger::envelope::SharedEnvelope;
use crate::ledger::tx::{Proposal, TxId};
use crate::mempool::Reject;

pub use crate::ledger::codec::WireError;
pub use crate::ledger::envelope::{
    decode_envelope, decode_proposal, encode_envelope, encode_proposal,
};

/// Decode one envelope out of a larger payload, carving its canonical
/// byte span into a fresh [`SharedEnvelope`] (decoded form pre-seeded, so
/// nothing downstream re-parses).
pub fn decode_shared(r: &mut Reader<'_>) -> Result<SharedEnvelope, WireError> {
    let start = r.pos();
    let env = decode_envelope(r)?;
    let bytes = r.underlying()[start..r.pos()].to_vec();
    Ok(SharedEnvelope::from_wire_decoded(bytes, env))
}

/// Minimum wire size of an envelope: eight length/count prefixes plus the
/// nonce, all fields empty. Bounds `Reader::count` on envelope sequences.
const MIN_ENVELOPE: usize = 8 * 4 + 8;

/// A consensus payload: one cut batch for one channel. Envelope buffers
/// are spliced, not re-encoded.
pub fn encode_batch(channel: &str, envs: &[SharedEnvelope]) -> Vec<u8> {
    let mut w = Writer::new();
    w.str(channel).u32(envs.len() as u32);
    for e in envs {
        e.write_to(&mut w);
    }
    w.finish()
}

/// Decode a consensus payload into (channel, envelopes).
pub fn decode_batch(buf: &[u8]) -> Result<(String, Vec<SharedEnvelope>), WireError> {
    let mut r = Reader::new(buf);
    let channel = r.str()?;
    let n = r.count(MIN_ENVELOPE)?;
    let mut envs = Vec::with_capacity(n);
    for _ in 0..n {
        envs.push(decode_shared(&mut r)?);
    }
    if !r.done() {
        return Err(WireError::malformed("trailing bytes in batch"));
    }
    Ok((channel, envs))
}

fn code_to_u8(c: ValidationCode) -> u8 {
    match c {
        ValidationCode::Valid => 0,
        ValidationCode::MvccConflict => 1,
        ValidationCode::EndorsementPolicyFailure => 2,
        ValidationCode::DuplicateTxId => 3,
    }
}

fn code_from_u8(b: u8) -> Result<ValidationCode, WireError> {
    match b {
        0 => Ok(ValidationCode::Valid),
        1 => Ok(ValidationCode::MvccConflict),
        2 => Ok(ValidationCode::EndorsementPolicyFailure),
        3 => Ok(ValidationCode::DuplicateTxId),
        other => Err(WireError::Malformed(format!("unknown validation code {other}"))),
    }
}

fn reject_to_u8(rej: Reject) -> u8 {
    match rej {
        Reject::PoolFull => 0,
        Reject::RateLimited => 1,
        Reject::Duplicate => 2,
        Reject::BadSignature => 3,
        Reject::PolicyUnsatisfiable => 4,
        Reject::StaleReadSet => 5,
        Reject::Shutdown => 6,
    }
}

fn reject_from_u8(b: u8) -> Result<Reject, WireError> {
    match b {
        0 => Ok(Reject::PoolFull),
        1 => Ok(Reject::RateLimited),
        2 => Ok(Reject::Duplicate),
        3 => Ok(Reject::BadSignature),
        4 => Ok(Reject::PolicyUnsatisfiable),
        5 => Ok(Reject::StaleReadSet),
        6 => Ok(Reject::Shutdown),
        other => Err(WireError::Malformed(format!("unknown reject code {other}"))),
    }
}

fn digest(r: &mut Reader<'_>) -> Result<Digest, WireError> {
    let b: [u8; 32] =
        r.bytes()?.try_into().map_err(|_| WireError::malformed("bad digest length"))?;
    Ok(Digest(b))
}

/// Serialize a committed block: header fields, ordered envelopes (spliced
/// canonical buffers — the single copy into the ledger store), and the
/// commit-time validation codes (one byte per tx). The header digests are
/// stored as written — not recomputed on decode — so a tampered payload
/// still fails `Block::verify_data_hash` after a roundtrip.
pub fn encode_block(b: &Block, w: &mut Writer) {
    w.u64(b.header.number);
    w.bytes(&b.header.prev_hash.0);
    w.bytes(&b.header.data_hash.0);
    w.u32(b.txs.len() as u32);
    for e in &b.txs {
        e.write_to(w);
    }
    w.u32(b.validation.len() as u32);
    for c in &b.validation {
        w.u8(code_to_u8(*c));
    }
}

/// Deserialize one block (inverse of [`encode_block`]).
pub fn decode_block(r: &mut Reader<'_>) -> Result<Block, WireError> {
    let number = r.u64()?;
    let prev_hash = digest(r)?;
    let data_hash = digest(r)?;
    let ntxs = r.count(MIN_ENVELOPE)?;
    let mut txs = Vec::with_capacity(ntxs);
    for _ in 0..ntxs {
        txs.push(decode_shared(r)?);
    }
    let ncodes = r.count(1)?;
    if ncodes != ntxs {
        return Err(WireError::Malformed(format!("{ncodes} validation codes for {ntxs} txs")));
    }
    let mut validation = Vec::with_capacity(ncodes);
    for _ in 0..ncodes {
        validation.push(code_from_u8(r.u8()?)?);
    }
    Ok(Block { header: BlockHeader { number, prev_hash, data_hash }, txs, validation })
}

/// Correlation id pairing a [`Request`] with its [`Response`] on one
/// connection. Allocated by the client; echoed verbatim by the server.
pub type RequestId = u64;

/// Client → server frames.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    /// Simulate + endorse a proposal; the server answers
    /// [`Response::Endorsed`] with the canonical envelope bytes (or
    /// [`Response::Failed`]).
    Endorse { id: RequestId, proposal: Proposal },
    /// Submit a canonical envelope for ordering. The server answers
    /// [`Response::Accepted`] / [`Response::Rejected`]; commit resolution
    /// streams back later as an [`Event`] on the same connection.
    Submit { id: RequestId, envelope: SharedEnvelope },
    /// Query one channel's chain position (height, tip hash, state root).
    Status { id: RequestId, channel: String },
}

/// Server → client frames correlated to a [`Request`].
#[derive(Clone, Debug, PartialEq)]
pub enum Response {
    /// Endorsement succeeded; carries the canonical envelope encoding the
    /// client should submit back verbatim.
    Endorsed { id: RequestId, envelope: SharedEnvelope },
    /// Submission admitted to the mempool; an [`Event`] will resolve it.
    Accepted { id: RequestId, tx_id: TxId },
    /// Submission refused at admission.
    Rejected { id: RequestId, reject: Reject },
    /// The request failed outright (endorsement error, unknown channel).
    Failed { id: RequestId, reason: String },
    /// Chain position snapshot for a [`Request::Status`].
    Status { id: RequestId, height: u64, tip: Digest, state_root: Digest },
}

/// Server → client frames not correlated to any request: the commit
/// stream that backs remote `SubmitHandle` resolution.
#[derive(Clone, Debug, PartialEq)]
pub enum Event {
    /// A transaction reached a committed block (the commit-time
    /// [`ValidationCode`] says whether it validated).
    Committed { channel: String, tx_id: TxId, block: u64, code: ValidationCode },
    /// A transaction was dropped before commit (relay loss, shutdown).
    Dropped { channel: String, tx_id: TxId, reject: Reject },
}

/// One protocol frame — the unit the transport length-prefixes.
#[derive(Clone, Debug, PartialEq)]
pub enum Frame {
    Request(Request),
    Response(Response),
    Event(Event),
}

/// Write an envelope as a length-prefixed field (canonical buffer
/// spliced, not re-encoded).
fn put_envelope(w: &mut Writer, env: &SharedEnvelope) {
    w.u32(env.encoded_len() as u32);
    env.write_to(w);
}

/// Read a length-prefixed envelope field, fully decoding it (the frame
/// boundary is the trust boundary) and carving the canonical bytes into a
/// [`SharedEnvelope`] with the decoded form pre-seeded.
fn get_envelope(r: &mut Reader<'_>) -> Result<SharedEnvelope, WireError> {
    let span = r.bytes()?;
    let mut er = Reader::new(span);
    let env = decode_envelope(&mut er)?;
    if !er.done() {
        return Err(WireError::malformed("trailing bytes in envelope field"));
    }
    Ok(SharedEnvelope::from_wire_decoded(span.to_vec(), env))
}

/// Serialize one frame (the transport adds the outer length prefix).
pub fn encode_frame(f: &Frame) -> Vec<u8> {
    let mut w = Writer::new();
    match f {
        Frame::Request(req) => {
            w.u8(0);
            match req {
                Request::Endorse { id, proposal } => {
                    w.u8(0).u64(*id);
                    encode_proposal(proposal, &mut w);
                }
                Request::Submit { id, envelope } => {
                    w.u8(1).u64(*id);
                    put_envelope(&mut w, envelope);
                }
                Request::Status { id, channel } => {
                    w.u8(2).u64(*id).str(channel);
                }
            }
        }
        Frame::Response(resp) => {
            w.u8(1);
            match resp {
                Response::Endorsed { id, envelope } => {
                    w.u8(0).u64(*id);
                    put_envelope(&mut w, envelope);
                }
                Response::Accepted { id, tx_id } => {
                    w.u8(1).u64(*id).bytes(&tx_id.0);
                }
                Response::Rejected { id, reject } => {
                    w.u8(2).u64(*id).u8(reject_to_u8(*reject));
                }
                Response::Failed { id, reason } => {
                    w.u8(3).u64(*id).str(reason);
                }
                Response::Status { id, height, tip, state_root } => {
                    w.u8(4).u64(*id).u64(*height).bytes(&tip.0).bytes(&state_root.0);
                }
            }
        }
        Frame::Event(ev) => {
            w.u8(2);
            match ev {
                Event::Committed { channel, tx_id, block, code } => {
                    w.u8(0).str(channel).bytes(&tx_id.0).u64(*block).u8(code_to_u8(*code));
                }
                Event::Dropped { channel, tx_id, reject } => {
                    w.u8(1).str(channel).bytes(&tx_id.0).u8(reject_to_u8(*reject));
                }
            }
        }
    }
    w.finish()
}

/// Deserialize one frame; the buffer must contain exactly one frame.
pub fn decode_frame(buf: &[u8]) -> Result<Frame, WireError> {
    let mut r = Reader::new(buf);
    let frame = match r.u8()? {
        0 => Frame::Request(match r.u8()? {
            0 => Request::Endorse { id: r.u64()?, proposal: decode_proposal(&mut r)? },
            1 => Request::Submit { id: r.u64()?, envelope: get_envelope(&mut r)? },
            2 => Request::Status { id: r.u64()?, channel: r.str()? },
            t => return Err(WireError::Malformed(format!("unknown request tag {t}"))),
        }),
        1 => Frame::Response(match r.u8()? {
            0 => Response::Endorsed { id: r.u64()?, envelope: get_envelope(&mut r)? },
            1 => Response::Accepted { id: r.u64()?, tx_id: digest(&mut r)? },
            2 => Response::Rejected { id: r.u64()?, reject: reject_from_u8(r.u8()?)? },
            3 => Response::Failed { id: r.u64()?, reason: r.str()? },
            4 => Response::Status {
                id: r.u64()?,
                height: r.u64()?,
                tip: digest(&mut r)?,
                state_root: digest(&mut r)?,
            },
            t => return Err(WireError::Malformed(format!("unknown response tag {t}"))),
        }),
        2 => Frame::Event(match r.u8()? {
            0 => Event::Committed {
                channel: r.str()?,
                tx_id: digest(&mut r)?,
                block: r.u64()?,
                code: code_from_u8(r.u8()?)?,
            },
            1 => Event::Dropped {
                channel: r.str()?,
                tx_id: digest(&mut r)?,
                reject: reject_from_u8(r.u8()?)?,
            },
            t => return Err(WireError::Malformed(format!("unknown event tag {t}"))),
        }),
        t => return Err(WireError::Malformed(format!("unknown frame tag {t}"))),
    };
    if !r.done() {
        return Err(WireError::malformed("trailing bytes after frame"));
    }
    Ok(frame)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crypto::msp::{MemberId, Signature};
    use crate::ledger::state::Version;
    use crate::ledger::tx::{Endorsement, Envelope, Proposal, RwSet};
    use crate::util::check::check;
    use crate::util::prng::Prng;

    fn random_envelope(rng: &mut Prng) -> Envelope {
        let nargs = rng.below(4);
        Envelope {
            proposal: Proposal {
                channel: format!("shard{}", rng.below(8)),
                chaincode: "models".into(),
                function: "CreateModelUpdate".into(),
                args: (0..nargs).map(|i| format!("arg{i}-{}", rng.next_u64())).collect(),
                creator: MemberId::new(format!("org{}.client", rng.below(8))),
                nonce: rng.next_u64(),
            },
            rw_set: RwSet {
                reads: (0..rng.below(4))
                    .map(|i| {
                        let ver = if rng.below(2) == 0 {
                            None
                        } else {
                            Some(Version { block: rng.next_u64() % 100, tx: rng.below(10) as u32 })
                        };
                        (format!("rk{i}"), ver)
                    })
                    .collect(),
                writes: (0..rng.below(4))
                    .map(|i| {
                        let val = if rng.below(4) == 0 {
                            None
                        } else {
                            Some(rng.next_u64().to_le_bytes().to_vec())
                        };
                        (format!("wk{i}"), val)
                    })
                    .collect(),
            },
            endorsements: (0..rng.below(4))
                .map(|i| {
                    let mut sig = [0u8; 32];
                    for c in sig.chunks_mut(8) {
                        c.copy_from_slice(&rng.next_u64().to_le_bytes()[..c.len()]);
                    }
                    Endorsement {
                        endorser: MemberId::new(format!("org{i}.peer")),
                        signature: Signature(sig),
                    }
                })
                .collect(),
        }
    }

    fn random_digest(rng: &mut Prng) -> Digest {
        let mut d = [0u8; 32];
        for c in d.chunks_mut(8) {
            c.copy_from_slice(&rng.next_u64().to_le_bytes()[..c.len()]);
        }
        Digest(d)
    }

    fn random_frame(rng: &mut Prng) -> Frame {
        let rejects = [
            Reject::PoolFull,
            Reject::RateLimited,
            Reject::Duplicate,
            Reject::BadSignature,
            Reject::PolicyUnsatisfiable,
            Reject::StaleReadSet,
            Reject::Shutdown,
        ];
        let codes = [
            ValidationCode::Valid,
            ValidationCode::MvccConflict,
            ValidationCode::EndorsementPolicyFailure,
            ValidationCode::DuplicateTxId,
        ];
        match rng.below(10) {
            0 => Frame::Request(Request::Endorse {
                id: rng.next_u64(),
                proposal: random_envelope(rng).proposal,
            }),
            1 => Frame::Request(Request::Submit {
                id: rng.next_u64(),
                envelope: random_envelope(rng).into(),
            }),
            2 => Frame::Request(Request::Status {
                id: rng.next_u64(),
                channel: format!("shard{}", rng.below(8)),
            }),
            3 => Frame::Response(Response::Endorsed {
                id: rng.next_u64(),
                envelope: random_envelope(rng).into(),
            }),
            4 => Frame::Response(Response::Accepted {
                id: rng.next_u64(),
                tx_id: random_digest(rng),
            }),
            5 => Frame::Response(Response::Rejected {
                id: rng.next_u64(),
                reject: rejects[rng.below(rejects.len() as u64) as usize],
            }),
            6 => Frame::Response(Response::Failed {
                id: rng.next_u64(),
                reason: format!("err-{}", rng.next_u64()),
            }),
            7 => Frame::Response(Response::Status {
                id: rng.next_u64(),
                height: rng.next_u64() % 1000,
                tip: random_digest(rng),
                state_root: random_digest(rng),
            }),
            8 => Frame::Event(Event::Committed {
                channel: format!("shard{}", rng.below(8)),
                tx_id: random_digest(rng),
                block: rng.next_u64() % 1000,
                code: codes[rng.below(codes.len() as u64) as usize],
            }),
            _ => Frame::Event(Event::Dropped {
                channel: format!("shard{}", rng.below(8)),
                tx_id: random_digest(rng),
                reject: rejects[rng.below(rejects.len() as u64) as usize],
            }),
        }
    }

    #[test]
    fn property_envelope_roundtrip() {
        check("envelope-roundtrip", 40, |rng| {
            let env = random_envelope(rng);
            let mut w = Writer::new();
            encode_envelope(&env, &mut w);
            let buf = w.finish();
            let mut r = Reader::new(&buf);
            let back = decode_envelope(&mut r).unwrap();
            assert_eq!(back, env);
            assert!(r.done());
        });
    }

    #[test]
    fn proposal_codec_is_envelope_prefix() {
        // A proposal encoded alone must be byte-identical to the prefix of
        // the full envelope encoding — `parse_views` depends on that
        // layout identity, and so does the Endorse request frame.
        let mut rng = Prng::new(17);
        for _ in 0..16 {
            let env = random_envelope(&mut rng);
            let mut pw = Writer::new();
            encode_proposal(&env.proposal, &mut pw);
            let pbuf = pw.finish();
            let mut ew = Writer::new();
            encode_envelope(&env, &mut ew);
            let ebuf = ew.finish();
            assert_eq!(&ebuf[..pbuf.len()], &pbuf[..]);
            let mut r = Reader::new(&pbuf);
            assert_eq!(decode_proposal(&mut r).unwrap(), env.proposal);
            assert!(r.done());
        }
    }

    #[test]
    fn batch_roundtrip_preserves_order() {
        let mut rng = Prng::new(5);
        let envs: Vec<SharedEnvelope> =
            (0..7).map(|_| random_envelope(&mut rng).into()).collect();
        let buf = encode_batch("shard3", &envs);
        let (ch, back) = decode_batch(&buf).unwrap();
        assert_eq!(ch, "shard3");
        assert_eq!(back, envs);
        // Decoded envelopes carry the exact same canonical bytes.
        for (a, b) in back.iter().zip(&envs) {
            assert_eq!(a.as_bytes(), b.as_bytes());
            assert_eq!(a.envelope(), b.envelope());
        }
        // The degenerate batch (a timeout cut with nothing pending)
        // roundtrips too.
        let (ch, back) = decode_batch(&encode_batch("empty", &[])).unwrap();
        assert_eq!(ch, "empty");
        assert!(back.is_empty());
    }

    fn random_block(rng: &mut Prng, number: u64) -> Block {
        let txs: Vec<Envelope> = (0..1 + rng.below(4)).map(|_| random_envelope(rng)).collect();
        let mut b = Block::new(number, Digest([rng.below(256) as u8; 32]), txs);
        b.validation = (0..b.txs.len())
            .map(|_| match rng.below(4) {
                0 => ValidationCode::Valid,
                1 => ValidationCode::MvccConflict,
                2 => ValidationCode::EndorsementPolicyFailure,
                _ => ValidationCode::DuplicateTxId,
            })
            .collect();
        b
    }

    #[test]
    fn property_block_roundtrip() {
        check("block-roundtrip", 32, |rng| {
            let b = random_block(rng, rng.next_u64() % 1000);
            let mut w = Writer::new();
            encode_block(&b, &mut w);
            let buf = w.finish();
            let mut r = Reader::new(&buf);
            let back = decode_block(&mut r).unwrap();
            assert!(r.done());
            assert_eq!(back, b);
            assert_eq!(back.hash(), b.hash());
            assert!(back.verify_data_hash());
        });
    }

    #[test]
    fn block_decode_rejects_tamper_and_truncation() {
        let mut rng = Prng::new(9);
        let b = random_block(&mut rng, 3);
        let mut w = Writer::new();
        encode_block(&b, &mut w);
        let buf = w.finish();
        // Truncation at any point errors instead of panicking.
        for cut in [0, 1, buf.len() / 2, buf.len() - 1] {
            assert!(decode_block(&mut Reader::new(&buf[..cut])).is_err(), "cut at {cut}");
        }
        // A flipped payload byte either fails to decode or decodes to a
        // block whose stored data hash no longer matches the envelopes —
        // the tamper check moves to `verify_data_hash`, exactly as for an
        // in-memory block.
        let mut flipped = buf.clone();
        // Header is 80 bytes (number + 2 length-prefixed digests); byte 85
        // sits inside the first envelope's payload.
        flipped[85] ^= 0xFF;
        if let Ok(back) = decode_block(&mut Reader::new(&flipped)) {
            assert!(!back.verify_data_hash());
        }
        // An unknown validation code errors.
        let mut bad_code = buf;
        let last = bad_code.len() - 1;
        bad_code[last] = 99;
        assert!(decode_block(&mut Reader::new(&bad_code)).is_err());
    }

    #[test]
    fn corrupt_batch_errors() {
        let mut rng = Prng::new(6);
        let buf = encode_batch("c", &[random_envelope(&mut rng).into()]);
        assert!(decode_batch(&buf[..buf.len() - 2]).is_err());
        let mut extra = buf.clone();
        extra.push(0);
        assert!(decode_batch(&extra).is_err());
    }

    /// Satellite: round-trip for every frame kind, encode → decode
    /// byte-identical on re-encode.
    #[test]
    fn property_frame_roundtrip() {
        check("frame-roundtrip", 60, |rng| {
            let f = random_frame(rng);
            let buf = encode_frame(&f);
            let back = decode_frame(&buf).unwrap();
            assert_eq!(back, f);
            assert_eq!(encode_frame(&back), buf);
        });
    }

    /// Satellite: a Submit frame carrying a large (multi-KiB) envelope —
    /// the batch-bytes ceiling end of the size range — survives intact
    /// with its canonical buffer carved out verbatim.
    #[test]
    fn submit_frame_carries_max_size_envelope() {
        let mut rng = Prng::new(21);
        let mut env = random_envelope(&mut rng);
        env.rw_set.writes.push(("big".into(), Some(vec![0xAB; 512 * 1024])));
        let se = SharedEnvelope::from(env);
        let f = Frame::Request(Request::Submit { id: 7, envelope: se.clone() });
        let buf = encode_frame(&f);
        let Frame::Request(Request::Submit { id, envelope }) = decode_frame(&buf).unwrap()
        else {
            panic!("wrong frame kind");
        };
        assert_eq!(id, 7);
        assert_eq!(envelope.as_bytes(), se.as_bytes());
        assert_eq!(envelope.tx_id(), se.tx_id());
    }

    /// Satellite: decoding truncated or bit-flipped frames at every byte
    /// offset never panics — truncation always errors, and a flipped byte
    /// either errors or decodes to some (different or equal) valid frame.
    #[test]
    fn property_frame_decode_never_panics() {
        check("frame-decode-hostile", 12, |rng| {
            let f = random_frame(rng);
            let buf = encode_frame(&f);
            for cut in 0..buf.len() {
                assert!(decode_frame(&buf[..cut]).is_err(), "cut at {cut}");
            }
            for i in 0..buf.len() {
                let mut flipped = buf.clone();
                flipped[i] ^= 1 << (rng.below(8) as u32);
                let _ = decode_frame(&flipped);
            }
        });
    }

    /// Satellite: length and count prefixes that lie about the payload
    /// error out before any allocation is sized from them.
    #[test]
    fn hostile_length_prefixes_never_overallocate() {
        // An envelope whose arg count claims 2^32-1 entries.
        let mut w = Writer::new();
        w.str("ch").str("cc").str("fn").u32(u32::MAX);
        let buf = w.finish();
        let err = decode_envelope(&mut Reader::new(&buf)).unwrap_err();
        assert!(!err.is_truncated(), "lying count is malformed: {err:?}");
        // A batch that claims 2^32-1 envelopes.
        let mut w = Writer::new();
        w.str("ch").u32(u32::MAX);
        assert!(decode_batch(&w.finish()).is_err());
        // A Submit frame whose envelope length field runs past the frame.
        let mut w = Writer::new();
        w.u8(0).u8(1).u64(1).u32(1 << 30);
        let err = decode_frame(&w.finish()).unwrap_err();
        assert!(err.is_truncated(), "{err:?}");
        // A block that declares more validation codes than txs.
        let mut rng = Prng::new(13);
        let b = random_block(&mut rng, 1);
        let mut w = Writer::new();
        encode_block(&b, &mut w);
        let mut buf = w.finish();
        // The codes count sits right before the trailing code bytes.
        let codes_at = buf.len() - b.validation.len() - 4;
        buf[codes_at..codes_at + 4].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(decode_block(&mut Reader::new(&buf)).is_err());
    }

    /// Torn-vs-malformed classification drives transport behaviour: a cut
    /// frame reports `Truncated` (retryable), a bad tag reports
    /// `Malformed` (close the connection).
    #[test]
    fn frame_errors_classify_torn_vs_malformed() {
        let f = Frame::Response(Response::Failed { id: 3, reason: "nope".into() });
        let buf = encode_frame(&f);
        let err = decode_frame(&buf[..buf.len() - 1]).unwrap_err();
        assert!(err.is_truncated(), "{err:?}");
        let mut bad = buf.clone();
        bad[0] = 9; // unknown frame tag
        let err = decode_frame(&bad).unwrap_err();
        assert!(!err.is_truncated(), "{err:?}");
        let mut trailing = buf;
        trailing.push(0);
        assert!(decode_frame(&trailing).is_err());
    }
}
