//! Federated-learning substrate: synthetic datasets, non-IID partitioners,
//! clients (honest and malicious), DP accounting, and the Flower-style
//! round coordination that the sharded workflow drives.

pub mod client;
pub mod datasets;
pub mod dp;
pub mod partition;

pub use client::{Behavior, DpConfig, FlClient, TrainConfig};
pub use datasets::SynthDataset;
