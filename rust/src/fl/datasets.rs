//! Deterministic synthetic datasets standing in for MNIST / CIFAR-10 /
//! LEAF-FEMNIST (DESIGN.md §2: no network access in this environment).
//!
//! Each class has a fixed smoothed prototype "image"; samples are the
//! prototype plus per-sample noise and a random shift, so the task is
//! learnable by the MLP yet non-trivial. The FEMNIST analogue additionally
//! applies a per-writer pixel transform so writer-partitioned splits are
//! genuinely non-IID in feature space (as handwriting style is).

use crate::util::prng::Prng;

/// A dense classification dataset (row-major features).
#[derive(Clone, Debug)]
pub struct SynthDataset {
    pub x: Vec<f32>,
    pub y: Vec<i32>,
    pub dim: usize,
    pub classes: usize,
}

impl SynthDataset {
    pub fn len(&self) -> usize {
        self.y.len()
    }

    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    pub fn row(&self, i: usize) -> &[f32] {
        &self.x[i * self.dim..(i + 1) * self.dim]
    }

    /// Extract the subset at `indices` (cloning rows).
    pub fn subset(&self, indices: &[usize]) -> SynthDataset {
        let mut x = Vec::with_capacity(indices.len() * self.dim);
        let mut y = Vec::with_capacity(indices.len());
        for &i in indices {
            x.extend_from_slice(self.row(i));
            y.push(self.y[i]);
        }
        SynthDataset { x, y, dim: self.dim, classes: self.classes }
    }

    /// Take a contiguous (start, len) slice as a new dataset.
    pub fn slice(&self, start: usize, len: usize) -> SynthDataset {
        let idx: Vec<usize> = (start..(start + len).min(self.len())).collect();
        self.subset(&idx)
    }

    /// Shuffled minibatches of exactly `batch` rows (drops the remainder,
    /// as FedAvg's local loop does).
    pub fn batches(&self, batch: usize, rng: &mut Prng) -> Vec<(Vec<f32>, Vec<i32>)> {
        let mut idx: Vec<usize> = (0..self.len()).collect();
        rng.shuffle(&mut idx);
        idx.chunks(batch)
            .filter(|c| c.len() == batch)
            .map(|c| {
                let mut x = Vec::with_capacity(batch * self.dim);
                let mut y = Vec::with_capacity(batch);
                for &i in c {
                    x.extend_from_slice(self.row(i));
                    y.push(self.y[i]);
                }
                (x, y)
            })
            .collect()
    }

    /// Flip every label (targeted data-poisoning attack).
    pub fn flip_labels(&mut self) {
        for y in &mut self.y {
            *y = (*y + 1) % self.classes as i32;
        }
    }
}

/// Smoothed class prototypes: random field re-usable across samples.
fn prototypes(rng: &mut Prng, classes: usize, dim: usize) -> Vec<Vec<f32>> {
    (0..classes)
        .map(|_| {
            let raw: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
            // 1D smoothing gives "stroke-like" correlated structure.
            let mut out = vec![0.0f32; dim];
            for i in 0..dim {
                let lo = i.saturating_sub(3);
                let hi = (i + 4).min(dim);
                out[i] = raw[lo..hi].iter().sum::<f32>() / (hi - lo) as f32;
            }
            out
        })
        .collect()
}

fn gen(
    task_seed: u64,
    sample_seed: u64,
    n: usize,
    dim: usize,
    classes: usize,
    noise: f32,
    shift: usize,
) -> SynthDataset {
    // Prototypes depend only on the *task* seed: train/eval/test splits of
    // the same task share class structure (different sample seeds).
    let mut prng = Prng::new(task_seed ^ 0x7A5C_17E5_EED5_0000);
    let protos = prototypes(&mut prng, classes, dim);
    let mut rng = Prng::new(sample_seed);
    let mut x = Vec::with_capacity(n * dim);
    let mut y = Vec::with_capacity(n);
    for _ in 0..n {
        let c = rng.below(classes);
        let s = if shift > 0 { rng.below(2 * shift + 1) as isize - shift as isize } else { 0 };
        for i in 0..dim {
            let j = (i as isize + s).rem_euclid(dim as isize) as usize;
            x.push(protos[c][j] + noise * rng.normal() as f32);
        }
        y.push(c as i32);
    }
    SynthDataset { x, y, dim, classes }
}

/// MNIST analogue: strong prototypes, light noise, small shifts.
pub fn mnist_like(task_seed: u64, sample_seed: u64, n: usize, dim: usize, classes: usize) -> SynthDataset {
    gen(task_seed, sample_seed, n, dim, classes, 0.35, 2)
}

/// CIFAR-10 analogue: noisier, larger shifts (harder task).
pub fn cifar_like(task_seed: u64, sample_seed: u64, n: usize, dim: usize, classes: usize) -> SynthDataset {
    gen(task_seed, sample_seed, n, dim, classes, 0.8, 6)
}

/// FEMNIST analogue: per-writer style transform (fixed gain field + bias)
/// applied on top of the shared prototypes, so different writers' data
/// differ in feature space, not just label mix.
pub fn femnist_like(
    task_seed: u64,
    sample_seed: u64,
    n: usize,
    dim: usize,
    classes: usize,
    writer: u64,
) -> SynthDataset {
    let mut base = gen(task_seed, sample_seed, n, dim, classes, 0.35, 2);
    let mut wrng = Prng::new(task_seed ^ writer.wrapping_mul(0xA24B_AED4_963E_E407));
    let gain: Vec<f32> = (0..dim).map(|_| 0.7 + 0.6 * wrng.next_f32()).collect();
    let bias: Vec<f32> = (0..dim).map(|_| 0.2 * wrng.normal() as f32).collect();
    for r in 0..base.len() {
        for i in 0..dim {
            base.x[r * dim + i] = base.x[r * dim + i] * gain[i] + bias[i];
        }
    }
    base
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_generation() {
        let a = mnist_like(1, 1, 100, 784, 10);
        let b = mnist_like(1, 1, 100, 784, 10);
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
        let c = mnist_like(1, 2, 100, 784, 10);
        assert_ne!(a.x, c.x);
    }

    #[test]
    fn all_classes_present_and_labels_in_range() {
        let d = mnist_like(3, 3, 2000, 784, 10);
        for c in 0..10 {
            assert!(d.y.contains(&c), "class {c} missing");
        }
        assert!(d.y.iter().all(|&y| (0..10).contains(&y)));
    }

    #[test]
    fn class_structure_is_learnable() {
        // Same-class rows must be closer (on average) than cross-class rows.
        let d = mnist_like(4, 4, 400, 200, 10);
        let dist = |a: &[f32], b: &[f32]| -> f64 {
            a.iter().zip(b).map(|(&x, &y)| ((x - y) as f64).powi(2)).sum()
        };
        let (mut same, mut diff, mut ns, mut nd) = (0.0, 0.0, 0, 0);
        for i in 0..100 {
            for j in (i + 1)..100 {
                let dd = dist(d.row(i), d.row(j));
                if d.y[i] == d.y[j] {
                    same += dd;
                    ns += 1;
                } else {
                    diff += dd;
                    nd += 1;
                }
            }
        }
        assert!(same / ns as f64 * 1.5 < diff / nd as f64);
    }

    #[test]
    fn writer_transforms_differ() {
        let a = femnist_like(1, 1, 50, 100, 10, 0);
        let b = femnist_like(1, 1, 50, 100, 10, 1);
        assert_eq!(a.y, b.y); // same underlying samples…
        assert_ne!(a.x, b.x); // …different writer style
    }

    #[test]
    fn batches_shape_and_coverage() {
        let d = mnist_like(5, 5, 105, 50, 10);
        let mut rng = Prng::new(1);
        let bs = d.batches(20, &mut rng);
        assert_eq!(bs.len(), 5); // 105 / 20 -> 5 full batches
        for (x, y) in &bs {
            assert_eq!(x.len(), 20 * 50);
            assert_eq!(y.len(), 20);
        }
    }

    #[test]
    fn flip_labels_changes_all() {
        let mut d = mnist_like(6, 6, 50, 20, 10);
        let orig = d.y.clone();
        d.flip_labels();
        assert!(d.y.iter().zip(&orig).all(|(a, b)| a != b));
    }

    #[test]
    fn subset_and_slice() {
        let d = mnist_like(7, 7, 30, 10, 10);
        let s = d.subset(&[0, 5, 7]);
        assert_eq!(s.len(), 3);
        assert_eq!(s.row(1), d.row(5));
        let sl = d.slice(28, 10);
        assert_eq!(sl.len(), 2); // clipped at the end
    }
}
