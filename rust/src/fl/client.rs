//! FL clients: local training over the PJRT runtime, with honest and
//! adversarial behaviours (label flip, noise, boosting, Sybil, lazy).

use anyhow::Result;

use super::datasets::SynthDataset;
use crate::defense::pn;
use crate::runtime::ops::{FlatParams, ModelOps};
use crate::util::prng::Prng;

/// Local-training hyperparameters (paper: B in {10, 20}, E in {1, 5, 15},
/// eta_k = 1e-2).
#[derive(Clone, Copy, Debug)]
pub struct TrainConfig {
    pub batch: usize,
    pub epochs: usize,
    pub lr: f32,
    pub dp: Option<DpConfig>,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig { batch: 10, epochs: 1, lr: 1e-2, dp: None }
    }
}

/// DP-SGD settings (paper: noise 0.4, clip 1.2, (eps, delta) = (5, 1e-5)).
#[derive(Clone, Copy, Debug)]
pub struct DpConfig {
    pub clip: f32,
    pub noise_mult: f32,
    pub delta: f64,
}

impl Default for DpConfig {
    fn default() -> Self {
        DpConfig { clip: 1.2, noise_mult: 0.4, delta: 1e-5 }
    }
}

/// Client behaviour during a round.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Behavior {
    Honest,
    /// Data poisoning: train on flipped labels.
    LabelFlip,
    /// Model poisoning: submit random noise of the given scale (x100 -> DOS).
    NoiseUpdate,
    /// Boost the honest delta by `factor` (backdoor amplification).
    Boost(u32),
    /// Lazy: copy the victim client's published update (PN detection target).
    Lazy { victim: usize },
}

/// One federated client.
pub struct FlClient {
    pub id: usize,
    pub data: SynthDataset,
    pub behavior: Behavior,
    pub rng: Prng,
    /// PN seed for this round's lazy-client defence (revealed post-round).
    pub pn_seed: u64,
    /// Steps taken so far (for the DP accountant).
    pub dp_steps: u64,
}

/// A produced local update plus metadata the workflow pins on-chain.
#[derive(Clone, Debug)]
pub struct LocalUpdate {
    pub client_id: usize,
    pub params: FlatParams,
    pub train_loss: f64,
    pub samples: usize,
    pub pn_seed: u64,
}

impl FlClient {
    pub fn new(id: usize, data: SynthDataset, behavior: Behavior, rng: Prng) -> FlClient {
        let mut rng = rng;
        let pn_seed = rng.next_u64();
        FlClient { id, data, behavior, rng, pn_seed, dp_steps: 0 }
    }

    /// Run local training from the global params (paper Eq. 3-4) and return
    /// the update this client *publishes* (behaviour applied).
    pub fn train(
        &mut self,
        ops: &ModelOps,
        global: &FlatParams,
        cfg: &TrainConfig,
    ) -> Result<LocalUpdate> {
        let mut data = self.data.clone();
        if self.behavior == Behavior::LabelFlip {
            data.flip_labels();
        }
        if let Behavior::NoiseUpdate = self.behavior {
            // Pure model poisoning: no training at all.
            let params: FlatParams =
                global.iter().map(|&g| g + 0.5 * self.rng.normal() as f32).collect();
            return Ok(LocalUpdate {
                client_id: self.id,
                params,
                train_loss: f64::NAN,
                samples: data.len(),
                pn_seed: self.pn_seed,
            });
        }
        let mut params = global.clone();
        let mut losses = Vec::new();
        for _ in 0..cfg.epochs {
            for (x, y) in data.batches(cfg.batch, &mut self.rng) {
                let (next, loss) = match cfg.dp {
                    Some(dp) if cfg.batch == 32 => {
                        self.dp_steps += 1;
                        ops.dp_train_step(
                            params,
                            &x,
                            &y,
                            cfg.lr,
                            self.rng.next_u64() as i32,
                            dp.clip,
                            dp.noise_mult,
                        )?
                    }
                    _ => ops.train_step(params, &x, &y, cfg.lr)?,
                };
                params = next;
                losses.push(loss);
            }
        }
        if let Behavior::Boost(factor) = self.behavior {
            for (p, g) in params.iter_mut().zip(global) {
                *p = g + (*p - g) * factor as f32;
            }
        }
        Ok(LocalUpdate {
            client_id: self.id,
            params,
            train_loss: crate::util::mean(&losses),
            samples: data.len(),
            pn_seed: self.pn_seed,
        })
    }

    /// Publish with the PN sequence applied (paper §5 lazy-client defence).
    pub fn publish_with_pn(&self, mut update: LocalUpdate, amplitude: f32) -> LocalUpdate {
        pn::apply_pn(&mut update.params, self.pn_seed, amplitude);
        update
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fl::datasets;

    fn client(behavior: Behavior, seed: u64, ops: &ModelOps) -> FlClient {
        let data = datasets::mnist_like(1, seed, 120, ops.input_dim(), 10);
        FlClient::new(0, data, behavior, Prng::new(seed))
    }

    #[test]
    fn honest_training_reduces_loss() {
        let Some(ops) = crate::runtime::shared_ops() else { return };
        let mut c = client(Behavior::Honest, 1, &ops);
        let global = ops.init_params(0).unwrap();
        let cfg = TrainConfig { batch: 10, epochs: 5, lr: 0.05, dp: None };
        let up = c.train(&ops, &global, &cfg).unwrap();
        assert!(up.train_loss.is_finite());
        assert_ne!(up.params, global);
        // Re-train from the produced params: loss should be lower on avg.
        let mut c2 = client(Behavior::Honest, 1, &ops);
        let up2 = c2.train(&ops, &up.params, &cfg).unwrap();
        assert!(up2.train_loss < up.train_loss, "{} !< {}", up2.train_loss, up.train_loss);
    }

    #[test]
    fn dp_training_works_at_batch_32() {
        let Some(ops) = crate::runtime::shared_ops() else { return };
        let mut c = client(Behavior::Honest, 2, &ops);
        let global = ops.init_params(0).unwrap();
        let cfg = TrainConfig {
            batch: 32,
            epochs: 1,
            lr: 0.01,
            dp: Some(DpConfig::default()),
        };
        let up = c.train(&ops, &global, &cfg).unwrap();
        assert!(up.train_loss.is_finite());
        assert!(c.dp_steps > 0);
    }

    #[test]
    fn boost_scales_delta() {
        let Some(ops) = crate::runtime::shared_ops() else { return };
        let global = ops.init_params(0).unwrap();
        let cfg = TrainConfig { batch: 10, epochs: 1, lr: 0.01, dp: None };
        let mut honest = client(Behavior::Honest, 3, &ops);
        let mut boosted = client(Behavior::Boost(10), 3, &ops);
        let uh = honest.train(&ops, &global, &cfg).unwrap();
        let ub = boosted.train(&ops, &global, &cfg).unwrap();
        let norm = |u: &LocalUpdate| -> f64 {
            u.params
                .iter()
                .zip(&global)
                .map(|(&p, &g)| ((p - g) as f64).powi(2))
                .sum::<f64>()
                .sqrt()
        };
        let (nh, nb) = (norm(&uh), norm(&ub));
        assert!(nb > 5.0 * nh, "boosted {nb} vs honest {nh}");
    }

    #[test]
    fn noise_update_skips_training() {
        let Some(ops) = crate::runtime::shared_ops() else { return };
        let global = ops.init_params(0).unwrap();
        let mut evil = client(Behavior::NoiseUpdate, 4, &ops);
        let up = evil
            .train(&ops, &global, &TrainConfig::default())
            .unwrap();
        assert!(up.train_loss.is_nan());
        assert_ne!(up.params, global);
    }

    #[test]
    fn pn_publication_is_detectable() {
        let Some(ops) = crate::runtime::shared_ops() else { return };
        let global = ops.init_params(0).unwrap();
        let cfg = TrainConfig { batch: 10, epochs: 1, lr: 0.01, dp: None };
        let mut c = client(Behavior::Honest, 5, &ops);
        let up = c.train(&ops, &global, &cfg).unwrap();
        let published = c.publish_with_pn(up, 1e-3);
        // Delta from global correlates with the client's own PN.
        let delta: Vec<f32> =
            published.params.iter().zip(&global).map(|(&p, &g)| p - g).collect();
        assert!(pn::pn_correlation(&delta, c.pn_seed, 1e-3) > 0.2);
    }
}
