//! Differential-privacy accounting for the DP-SGD train path.
//!
//! The lowered `dp_train_step` clips the batch gradient to `clip` and adds
//! Gaussian noise `noise_mult * clip / B` (an Opacus-style configuration;
//! the paper uses (eps, delta) = (5, 1e-5), noise multiplier 0.4, max grad
//! norm 1.2). This module converts (q, sigma, steps, delta) into an epsilon
//! via Renyi-DP composition of the subsampled Gaussian mechanism, using the
//! standard `q^2 * alpha / sigma^2`-scale upper bound (Abadi et al., Lemma 3
//! regime; documented approximation — tight accounting needs the full
//! moments integral, which is out of scope here).

/// RDP of one subsampled-Gaussian step at order `alpha` (upper bound).
fn rdp_step(q: f64, sigma: f64, alpha: f64) -> f64 {
    if q <= 0.0 {
        return 0.0;
    }
    if q >= 1.0 {
        // Plain Gaussian mechanism.
        return alpha / (2.0 * sigma * sigma);
    }
    // Upper-bound the subsampled mechanism; the 3.5 constant follows the
    // classical moments-accountant bound's regime.
    (3.5 * q * q * alpha) / (sigma * sigma)
}

/// Epsilon after `steps` compositions, optimised over RDP orders.
pub fn epsilon(q: f64, sigma: f64, steps: u64, delta: f64) -> f64 {
    assert!(sigma > 0.0 && delta > 0.0 && delta < 1.0);
    let mut best = f64::INFINITY;
    // Scan integer and fractional orders.
    let mut alpha = 1.25;
    while alpha <= 256.0 {
        let rdp = steps as f64 * rdp_step(q, sigma, alpha);
        let eps = rdp + (1.0 / delta).ln() / (alpha - 1.0);
        best = best.min(eps);
        alpha *= 1.1;
    }
    best
}

/// Steps affordable under a target epsilon (binary search).
pub fn steps_for_epsilon(q: f64, sigma: f64, delta: f64, target_eps: f64) -> u64 {
    let (mut lo, mut hi) = (0u64, 1u64 << 32);
    while lo < hi {
        let mid = lo + (hi - lo) / 2 + 1;
        if epsilon(q, sigma, mid, delta) <= target_eps {
            lo = mid;
        } else {
            hi = mid - 1;
        }
    }
    lo
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn epsilon_monotone_in_steps_and_noise() {
        let e1 = epsilon(0.01, 1.0, 100, 1e-5);
        let e2 = epsilon(0.01, 1.0, 1000, 1e-5);
        assert!(e2 > e1);
        let e3 = epsilon(0.01, 2.0, 1000, 1e-5);
        assert!(e3 < e2);
    }

    #[test]
    fn subsampling_amplifies_privacy() {
        let full = epsilon(1.0, 1.0, 100, 1e-5);
        let sub = epsilon(0.01, 1.0, 100, 1e-5);
        assert!(sub < full);
    }

    #[test]
    fn paper_configuration_is_finite_and_positive() {
        // noise multiplier 0.4, delta 1e-5, small sampling rate, 15 epochs
        // of ~100 steps — epsilon is in a plausible single-digit-to-tens
        // range for this loose bound.
        let eps = epsilon(0.05, 0.4, 1500, 1e-5);
        assert!(eps.is_finite() && eps > 0.0, "eps {eps}");
    }

    #[test]
    fn steps_for_epsilon_inverts() {
        let (q, sigma, delta) = (0.02, 1.0, 1e-5);
        let steps = steps_for_epsilon(q, sigma, delta, 5.0);
        assert!(steps > 0);
        assert!(epsilon(q, sigma, steps, delta) <= 5.0);
        assert!(epsilon(q, sigma, steps + steps / 2 + 1, delta) > 5.0 * 0.99);
    }
}
