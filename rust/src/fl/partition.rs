//! Client data partitioners: IID, Dirichlet non-IID (label skew), and
//! writer-based (the LEAF/FEMNIST split, paper §4.2).

use std::collections::HashMap;

use super::datasets::SynthDataset;
use crate::util::prng::Prng;

/// Evenly split classes between clients (the paper's IID setting).
pub fn iid(data: &SynthDataset, clients: usize, rng: &mut Prng) -> Vec<SynthDataset> {
    let mut idx: Vec<usize> = (0..data.len()).collect();
    rng.shuffle(&mut idx);
    (0..clients)
        .map(|c| {
            let share: Vec<usize> =
                idx.iter().skip(c).step_by(clients).copied().collect();
            data.subset(&share)
        })
        .collect()
}

/// Dirichlet(alpha) label-skew partition: for each class, split its samples
/// between clients with proportions drawn from Dirichlet(alpha). Small alpha
/// => strongly non-IID (each client dominated by few classes).
pub fn dirichlet(
    data: &SynthDataset,
    clients: usize,
    alpha: f64,
    rng: &mut Prng,
) -> Vec<SynthDataset> {
    let mut by_class: HashMap<i32, Vec<usize>> = HashMap::new();
    for (i, &y) in data.y.iter().enumerate() {
        by_class.entry(y).or_default().push(i);
    }
    let mut shares: Vec<Vec<usize>> = vec![Vec::new(); clients];
    let mut classes: Vec<i32> = by_class.keys().copied().collect();
    classes.sort_unstable();
    for c in classes {
        let mut idx = by_class.remove(&c).unwrap();
        rng.shuffle(&mut idx);
        let props = rng.dirichlet(alpha, clients);
        // cumulative cut points
        let n = idx.len();
        let mut start = 0usize;
        let mut acc = 0.0;
        for (cl, p) in props.iter().enumerate() {
            acc += p;
            let end = if cl == clients - 1 { n } else { (acc * n as f64).round() as usize };
            let end = end.clamp(start, n);
            shares[cl].extend_from_slice(&idx[start..end]);
            start = end;
        }
    }
    // Guarantee every client has at least one sample (move from the richest).
    for c in 0..clients {
        if shares[c].is_empty() {
            let richest =
                (0..clients).max_by_key(|&i| shares[i].len()).expect("clients > 0");
            if let Some(moved) = shares[richest].pop() {
                shares[c].push(moved);
            }
        }
    }
    shares.iter().map(|s| data.subset(s)).collect()
}

/// Writer-based split (FEMNIST): each client is a distinct writer with its
/// own style transform — non-IID in both features and label mix.
pub fn by_writer(
    task_seed: u64,
    clients: usize,
    samples_per_client: usize,
    dim: usize,
    classes: usize,
) -> Vec<SynthDataset> {
    (0..clients)
        .map(|w| {
            super::datasets::femnist_like(
                task_seed,
                task_seed.wrapping_add(w as u64 + 1),
                samples_per_client,
                dim,
                classes,
                w as u64,
            )
        })
        .collect()
}

/// Label-distribution skew measure: mean total-variation distance between
/// each client's label histogram and the global histogram (0 = IID).
pub fn label_skew(parts: &[SynthDataset], classes: usize) -> f64 {
    let total: usize = parts.iter().map(|p| p.len()).sum();
    let mut global = vec![0.0f64; classes];
    for p in parts {
        for &y in &p.y {
            global[y as usize] += 1.0;
        }
    }
    for g in &mut global {
        *g /= total as f64;
    }
    let mut acc = 0.0;
    for p in parts {
        let mut h = vec![0.0f64; classes];
        for &y in &p.y {
            h[y as usize] += 1.0;
        }
        for v in &mut h {
            *v /= p.len().max(1) as f64;
        }
        acc += h.iter().zip(&global).map(|(a, b)| (a - b).abs()).sum::<f64>() / 2.0;
    }
    acc / parts.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fl::datasets::mnist_like;
    use crate::util::check::check;

    #[test]
    fn iid_covers_everything_evenly() {
        let d = mnist_like(1, 1, 1000, 50, 10);
        let mut rng = Prng::new(1);
        let parts = iid(&d, 8, &mut rng);
        let total: usize = parts.iter().map(|p| p.len()).sum();
        assert_eq!(total, 1000);
        for p in &parts {
            assert!((p.len() as i64 - 125).abs() <= 8);
        }
        assert!(label_skew(&parts, 10) < 0.12, "skew {}", label_skew(&parts, 10));
    }

    #[test]
    fn dirichlet_low_alpha_is_skewed() {
        let d = mnist_like(2, 2, 4000, 50, 10);
        let mut rng = Prng::new(2);
        let iid_parts = iid(&d, 8, &mut rng);
        let skewed = dirichlet(&d, 8, 0.1, &mut rng);
        let mild = dirichlet(&d, 8, 100.0, &mut rng);
        let s_skewed = label_skew(&skewed, 10);
        let s_mild = label_skew(&mild, 10);
        let s_iid = label_skew(&iid_parts, 10);
        assert!(s_skewed > 0.4, "alpha=0.1 skew {s_skewed}");
        assert!(s_mild < 0.2, "alpha=100 skew {s_mild}");
        assert!(s_skewed > s_mild && s_mild >= s_iid * 0.5);
    }

    #[test]
    fn dirichlet_partition_is_exact_and_nonempty() {
        check("dirichlet-partition", 12, |rng| {
            let n = rng.range(200, 1000);
            let clients = rng.range(2, 12);
            let d = mnist_like(1, rng.next_u64(), n, 20, 10);
            let parts = dirichlet(&d, clients, 0.5, rng);
            assert_eq!(parts.iter().map(|p| p.len()).sum::<usize>(), n);
            assert!(parts.iter().all(|p| !p.is_empty()));
        });
    }

    #[test]
    fn writer_split_is_feature_non_iid() {
        let parts = by_writer(7, 4, 100, 30, 10);
        assert_eq!(parts.len(), 4);
        // Mean feature vectors differ across writers.
        let mean = |p: &SynthDataset| -> Vec<f32> {
            let mut m = vec![0.0f32; p.dim];
            for r in 0..p.len() {
                for (i, v) in p.row(r).iter().enumerate() {
                    m[i] += v;
                }
            }
            m.iter().map(|v| v / p.len() as f32).collect()
        };
        let m0 = mean(&parts[0]);
        let m1 = mean(&parts[1]);
        let diff: f32 = m0.iter().zip(&m1).map(|(a, b)| (a - b).abs()).sum();
        assert!(diff > 1.0, "writers look identical (diff {diff})");
    }
}
