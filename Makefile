# ScaleSFL build/verify entry points.
#
#   make check     - formatting + lints + tier-1 verify (CI gate)
#   make verify    - tier-1: release build + tests
#   make bench     - perf baselines (writes BENCH_mempool.json,
#                    BENCH_gateway.json, BENCH_validation.json)

.PHONY: check fmt clippy verify bench

check: fmt clippy verify

fmt:
	cargo fmt --check

clippy:
	cargo clippy --all-targets -- -D warnings

verify:
	cargo build --release
	cargo test -q

bench:
	cargo bench --bench mempool
	cargo bench --bench gateway_pipeline
	cargo bench --bench validation
