# ScaleSFL build/verify entry points.
#
#   make ci             - the full CI gate (identical to what
#                         .github/workflows/ci.yml runs): fmt + clippy +
#                         build (examples/benches/docs) + tests + the
#                         bench smoke gate (bench_check vs bench-baselines/)
#   make check          - formatting + lints + tier-1 verify
#   make verify         - tier-1: release build + tests
#   make bench          - full perf baselines (writes BENCH_mempool.json,
#                         BENCH_gateway.json, BENCH_validation.json,
#                         BENCH_relay.json, BENCH_telemetry.json,
#                         BENCH_durability.json, BENCH_consensus.json,
#                         BENCH_wire.json)
#   make bench-smoke    - fast deterministic bench runs (seconds, fixed
#                         seeds) into target/smoke/
#   make bench-baseline - refresh the committed CI baselines in
#                         bench-baselines/ from a fresh smoke run

.PHONY: ci check fmt clippy verify bench bench-smoke bench-baseline

ci:
	./ci.sh

check: fmt clippy verify

fmt:
	cargo fmt --check

clippy:
	cargo clippy --all-targets -- -D warnings

verify:
	cargo build --release
	cargo test -q

bench:
	cargo bench --bench mempool
	cargo bench --bench gateway_pipeline
	cargo bench --bench validation
	cargo bench --bench relay
	cargo bench --bench telemetry
	cargo bench --bench durability
	cargo bench --bench consensus
	cargo bench --bench wire

bench-smoke:
	rm -rf target/smoke
	cargo bench --bench mempool -- --smoke
	cargo bench --bench gateway_pipeline -- --smoke
	cargo bench --bench validation -- --smoke
	cargo bench --bench relay -- --smoke
	cargo bench --bench telemetry -- --smoke
	cargo bench --bench durability -- --smoke
	cargo bench --bench consensus -- --smoke
	cargo bench --bench wire -- --smoke

bench-baseline: bench-smoke
	mkdir -p bench-baselines
	cp target/smoke/BENCH_*.json bench-baselines/
	@echo "refreshed bench-baselines/ from raw measurements."
	@echo "IMPORTANT: re-pad the headline values before committing —"
	@echo "the gate trips at 20% past the committed headline, so leave"
	@echo "deliberate headroom above your machine's numbers"
	@echo "(see bench-baselines/README.md), then review and commit."
