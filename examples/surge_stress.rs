//! Surge stress (paper Figs. 6-7, live mode): first drive the *real*
//! ordering pipeline past its block-production knee and watch the sharded
//! mempool shed load instead of queueing unboundedly (no artifacts
//! needed); then, when PJRT artifacts are built, drive the full fabric
//! pipeline with real endorsement evaluations and show the calibrated DES
//! prediction for the same setup.
//!
//!     cargo run --release --example surge_stress

use std::sync::Arc;
use std::time::{Duration, Instant};

use scalesfl::caliper::des::{global_capacity, run_des, shard_capacity, DesConfig};
use scalesfl::caliper::real::run_real;
use scalesfl::caliper::Workload;
use scalesfl::crypto::msp::{CertificateAuthority, MemberId};
use scalesfl::fabric::chaincode::{Chaincode, TxContext};
use scalesfl::fabric::endorsement::EndorsementPolicy;
use scalesfl::fabric::orderer::{OrdererConfig, OrderingService};
use scalesfl::fabric::peer::Peer;
use scalesfl::fabric::Gateway;
use scalesfl::fl::client::TrainConfig;
use scalesfl::ledger::tx::{Envelope, Proposal};
use scalesfl::mempool::{MempoolConfig, MempoolRegistry, Reject};
use scalesfl::sim::{Partition, ScaleSfl, SimConfig};
use scalesfl::util::prng::Prng;

struct PutCc;
impl Chaincode for PutCc {
    fn name(&self) -> &str {
        "kv"
    }
    fn invoke(
        &self,
        ctx: &mut TxContext<'_>,
        _f: &str,
        args: &[String],
    ) -> Result<Vec<u8>, String> {
        ctx.put(&args[0], b"v".to_vec());
        Ok(vec![])
    }
}

fn endorse(peers: &[Arc<Peer>], prop: Proposal) -> Envelope {
    let mut endorsements = Vec::new();
    let mut rw = None;
    for p in peers {
        let (r, e, _) = p.endorse(&prop).unwrap();
        rw = Some(r);
        endorsements.push(e);
    }
    Envelope { proposal: prop, rw_set: rw.unwrap(), endorsements }
}

/// Substrate-only surge: a bounded mempool in front of a throttled orderer
/// at 2x the block-production knee. Expect nonzero shed, a bounded queue,
/// and flat committed-tx latency.
fn backpressure_demo() {
    println!("# mempool backpressure at 2x the ordering knee (no artifacts needed)");
    let ca = CertificateAuthority::new();
    let mut rng = Prng::new(9);
    let peers: Vec<Arc<Peer>> = (0..2)
        .map(|i| {
            let cred = ca.enroll(MemberId::new(format!("org{i}.peer")), &mut rng);
            Peer::new(cred, ca.clone())
        })
        .collect();
    let members: Vec<MemberId> = peers.iter().map(|p| p.member.clone()).collect();
    for p in &peers {
        p.join_channel("ch", EndorsementPolicy::MajorityOf(members.clone()));
        p.install_chaincode("ch", Arc::new(PutCc)).unwrap();
    }
    let lane_capacity = 64;
    let batch_size = 8;
    let min_block_interval = Duration::from_millis(25);
    let knee_tps = batch_size as f64 / min_block_interval.as_secs_f64(); // 320 tx/s
    let mempool = MempoolRegistry::new(MempoolConfig {
        lane_capacity,
        ..Default::default()
    });
    let orderer = OrderingService::start_with_mempool(
        OrdererConfig {
            batch_size,
            batch_timeout: Duration::from_millis(10),
            min_block_interval,
            tick: Duration::from_millis(1),
            ..Default::default()
        },
        peers.clone(),
        1,
        mempool,
    );
    let rx = peers[0].subscribe("ch").unwrap();

    let offered = 600usize;
    let offered_tps = knee_tps * 2.0;
    let start = Instant::now();
    let mut admitted = 0usize;
    let mut shed = 0usize;
    let mut worst_wait = 0.0f64;
    let mut submit_times = std::collections::HashMap::new();
    for i in 0..offered {
        if i % 4 == 0 {
            let due = start + Duration::from_secs_f64(i as f64 / offered_tps);
            if let Some(wait) = due.checked_duration_since(Instant::now()) {
                std::thread::sleep(wait);
            }
        }
        let env = endorse(
            &peers,
            Proposal {
                channel: "ch".into(),
                chaincode: "kv".into(),
                function: "Put".into(),
                args: vec![format!("k{i}")],
                creator: MemberId::new("stress-client"),
                nonce: i as u64,
            },
        );
        let tx_id = env.tx_id();
        match orderer.submit(env) {
            Ok(()) => {
                submit_times.insert(tx_id, Instant::now());
                admitted += 1;
            }
            Err(Reject::PoolFull) => shed += 1,
            Err(other) => println!("unexpected reject: {other}"),
        }
    }
    let mut committed = 0usize;
    while committed < admitted {
        let ev = rx.recv_timeout(Duration::from_secs(20)).expect("bounded queue drains");
        if let Some(at) = submit_times.get(&ev.tx_id) {
            worst_wait = worst_wait.max(at.elapsed().as_secs_f64());
            committed += 1;
        }
    }
    let stats = orderer.mempool().snapshot();
    println!(
        "offered {offered} @ {offered_tps:.0} tx/s (knee {knee_tps:.0}): admitted={admitted} shed={shed} committed={committed}"
    );
    println!(
        "queue high-water {} / cap {lane_capacity}; worst commit latency {:.3}s (bounded, no unbounded growth)",
        stats.depth_high_water, worst_wait
    );

    // Per-client rate caps: a greedy client is throttled at admission.
    let limited = MempoolRegistry::new(MempoolConfig {
        rate_limit: Some(20.0),
        rate_burst: 4.0,
        ..Default::default()
    });
    let orderer2 = OrderingService::start_with_mempool(
        OrdererConfig::default(),
        peers.clone(),
        2,
        limited,
    );
    let mut ok = 0;
    let mut limited_count = 0;
    for i in 0..10u64 {
        let env = endorse(
            &peers,
            Proposal {
                channel: "ch".into(),
                chaincode: "kv".into(),
                function: "Put".into(),
                args: vec![format!("r{i}")],
                creator: MemberId::new("greedy-client"),
                nonce: 1000 + i,
            },
        );
        match orderer2.submit(env) {
            Ok(()) => ok += 1,
            Err(Reject::RateLimited) => limited_count += 1,
            Err(other) => println!("unexpected reject: {other}"),
        }
    }
    println!(
        "rate cap (20 tx/s, burst 4): {ok} admitted, {limited_count} rate-limited of 10 rapid submissions\n"
    );
}

fn main() -> anyhow::Result<()> {
    backpressure_demo();

    let Some(ops) = scalesfl::runtime::shared_ops() else {
        println!("artifacts not built — skipping the live PJRT surge (run `make artifacts` first)");
        return Ok(());
    };
    // Small real deployment; endorsement evaluates on 512 samples.
    let cfg = SimConfig {
        shards: 2,
        peers_per_shard: 2,
        clients_per_shard: 2,
        samples_per_client: 40,
        eval_samples: 512,
        test_samples: 64,
        train: TrainConfig { batch: 10, epochs: 1, lr: 0.05, dp: None },
        partition: Partition::Iid,
        verify_aggregate: false,
        seed: 5,
        timeout: Duration::from_secs(8),
        ..Default::default()
    };
    let net = ScaleSfl::build(cfg, ops.clone())?;
    // Pre-store one valid model blob; every stress tx re-submits it under a
    // fresh (round, client) key, so each endorsement runs a real evaluation.
    let params = ops.init_params(77)?;
    let (digest, uri) = net.store.put(params);

    // Calibrate: one endorsement evaluation on this peer's split size.
    let cal = ops.calibrate(512, 3)?;
    println!("calibrated endorsement eval: {:.1} ms / update\n", cal.eval_s * 1e3);

    let gateways: Vec<Arc<Gateway>> = (0..net.shards.len())
        .map(|s| {
            let mut gw = Gateway::new(net.shards[s].peers.clone(), Arc::clone(&net.orderer));
            gw.timeout = Duration::from_secs(8);
            Arc::new(gw)
        })
        .collect();
    let shard_names: Vec<String> =
        net.shards.iter().map(|s| s.channel.clone()).collect();

    println!(
        "{:<10} {:>10} {:>10} {:>8} {:>8} {:>12}",
        "sent TPS", "tput", "avgLat(s)", "fail", "shed", "(real run)"
    );
    for (run, mult) in [(0u64, 0.5), (1, 1.5), (2, 4.0)] {
        // Real capacity here: evaluations serialize on 1 core across all
        // peers, so per-host capacity ~= 1/eval_s regardless of shards.
        let capacity = 1.0 / cal.eval_s / 4.0; // 4 endorsers share the core
        let tps = capacity * mult;
        let wl =
            Workload { txs: 24, send_tps: tps, workers: 2, timeout_s: 8.0, max_in_flight: 16 };
        let digest_hex = digest.hex();
        let uri = uri.clone();
        let names = shard_names.clone();
        let report = run_real("surge", &wl, &gateways, move |i| Proposal {
            channel: names[i % names.len()].clone(),
            chaincode: "models".into(),
            function: "CreateModelUpdate".into(),
            args: vec![
                // Unique round per (run, tx): no duplicate-key rejections.
                format!("{}", 1000 + run * 1000 + i as u64),
                format!("stress{i}"),
                digest_hex.clone(),
                uri.clone(),
                "10".into(),
            ],
            creator: MemberId::new("stress-client"),
            nonce: i as u64,
        });
        println!(
            "{:<10.2} {:>10.2} {:>10.3} {:>8} {:>8}",
            tps,
            report.throughput,
            report.avg_latency(),
            report.failed,
            report.shed
        );
    }
    let ingress = net.orderer.mempool().snapshot();
    println!(
        "ingress counters: admitted={} shed={} (pool_full={} rate_limited={}) expired={}",
        ingress.admitted,
        ingress.shed(),
        ingress.pool_full,
        ingress.rate_limited,
        ingress.expired
    );

    // DES prediction at the paper's 8-peer parallelism for contrast; the
    // bounded ingress pool turns the overload tail into shed load.
    println!("\nDES prediction (8-way peer parallelism, same eval cost, bounded ingress):");
    let mut des_cfg = DesConfig {
        shards: 2,
        endorsers_per_shard: 2,
        quorum: 2,
        eval_s: cal.eval_s,
        ..Default::default()
    };
    des_cfg.pool_capacity = (0.8 * 8.0 * shard_capacity(&des_cfg)).ceil() as usize;
    let cap = global_capacity(&des_cfg);
    println!(
        "{:<10} {:>10} {:>10} {:>8} {:>8}",
        "sent TPS", "tput", "avgLat(s)", "fail", "shed"
    );
    for mult in [0.5, 1.5, 4.0] {
        let wl = Workload {
            txs: 200,
            send_tps: cap * mult,
            workers: 2,
            timeout_s: 8.0,
            ..Default::default()
        };
        let r = run_des(&des_cfg, &wl, 42);
        println!(
            "{:<10.2} {:>10.2} {:>10.3} {:>8} {:>8}",
            cap * mult,
            r.throughput,
            r.avg_latency(),
            r.failed,
            r.shed
        );
    }
    println!("\nexpected: sub-capacity load commits fast; super-capacity load sheds at admission while committed latency stays bounded");
    Ok(())
}
