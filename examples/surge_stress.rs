//! Surge stress (paper Figs. 6-7, live mode): drive the *real* fabric
//! pipeline past saturation with actual PJRT endorsement evaluations and
//! watch latency climb and timeouts appear; then show the calibrated DES
//! prediction for the same setup.
//!
//!     cargo run --release --example surge_stress

use std::sync::Arc;
use std::time::Duration;

use scalesfl::caliper::des::{global_capacity, run_des, DesConfig};
use scalesfl::caliper::real::run_real;
use scalesfl::caliper::Workload;
use scalesfl::crypto::msp::MemberId;
use scalesfl::fabric::Gateway;
use scalesfl::fl::client::TrainConfig;
use scalesfl::ledger::tx::Proposal;
use scalesfl::sim::{Partition, ScaleSfl, SimConfig};

fn main() -> anyhow::Result<()> {
    let Some(ops) = scalesfl::runtime::shared_ops() else {
        anyhow::bail!("artifacts not built — run `make artifacts` first");
    };
    // Small real deployment; endorsement evaluates on 512 samples.
    let cfg = SimConfig {
        shards: 2,
        peers_per_shard: 2,
        clients_per_shard: 2,
        samples_per_client: 40,
        eval_samples: 512,
        test_samples: 64,
        train: TrainConfig { batch: 10, epochs: 1, lr: 0.05, dp: None },
        partition: Partition::Iid,
        verify_aggregate: false,
        seed: 5,
        timeout: Duration::from_secs(8),
        ..Default::default()
    };
    let net = ScaleSfl::build(cfg, ops.clone())?;
    // Pre-store one valid model blob; every stress tx re-submits it under a
    // fresh (round, client) key, so each endorsement runs a real evaluation.
    let params = ops.init_params(77)?;
    let (digest, uri) = net.store.put(params);

    // Calibrate: one endorsement evaluation on this peer's split size.
    let cal = ops.calibrate(512, 3)?;
    println!("calibrated endorsement eval: {:.1} ms / update\n", cal.eval_s * 1e3);

    let gateways: Vec<Arc<Gateway>> = (0..net.shards.len())
        .map(|s| {
            let mut gw = Gateway::new(net.shards[s].peers.clone(), Arc::clone(&net.orderer));
            gw.timeout = Duration::from_secs(8);
            Arc::new(gw)
        })
        .collect();
    let shard_names: Vec<String> =
        net.shards.iter().map(|s| s.channel.clone()).collect();

    println!("{:<10} {:>10} {:>10} {:>8} {:>12}", "sent TPS", "tput", "avgLat(s)", "fail", "(real run)");
    for (run, mult) in [(0u64, 0.5), (1, 1.5), (2, 4.0)] {
        // Real capacity here: evaluations serialize on 1 core across all
        // peers, so per-host capacity ~= 1/eval_s regardless of shards.
        let capacity = 1.0 / cal.eval_s / 4.0; // 4 endorsers share the core
        let tps = capacity * mult;
        let wl = Workload { txs: 24, send_tps: tps, workers: 2, timeout_s: 8.0 };
        let digest_hex = digest.hex();
        let uri = uri.clone();
        let names = shard_names.clone();
        let report = run_real("surge", &wl, &gateways, move |i| Proposal {
            channel: names[i % names.len()].clone(),
            chaincode: "models".into(),
            function: "CreateModelUpdate".into(),
            args: vec![
                // Unique round per (run, tx): no duplicate-key rejections.
                format!("{}", 1000 + run * 1000 + i as u64),
                format!("stress{i}"),
                digest_hex.clone(),
                uri.clone(),
                "10".into(),
            ],
            creator: MemberId::new("stress-client"),
            nonce: i as u64,
        });
        println!(
            "{:<10.2} {:>10.2} {:>10.3} {:>8} ",
            tps,
            report.throughput,
            report.avg_latency(),
            report.failed
        );
    }

    // DES prediction at the paper's 8-peer parallelism for contrast.
    println!("\nDES prediction (8-way peer parallelism, same eval cost):");
    let des_cfg = DesConfig {
        shards: 2,
        endorsers_per_shard: 2,
        quorum: 2,
        eval_s: cal.eval_s,
        ..Default::default()
    };
    let cap = global_capacity(&des_cfg);
    println!("{:<10} {:>10} {:>10} {:>8}", "sent TPS", "tput", "avgLat(s)", "fail");
    for mult in [0.5, 1.5, 4.0] {
        let wl =
            Workload { txs: 200, send_tps: cap * mult, workers: 2, timeout_s: 8.0 };
        let r = run_des(&des_cfg, &wl, 42);
        println!(
            "{:<10.2} {:>10.2} {:>10.3} {:>8}",
            cap * mult,
            r.throughput,
            r.avg_latency(),
            r.failed
        );
    }
    println!("\nexpected: sub-capacity load commits fast; super-capacity load queues, then times out");
    Ok(())
}
