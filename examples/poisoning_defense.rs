//! Defence study (the paper's §6 future-work experiment, implemented):
//! inject malicious clients and show the pluggable defences filter them.
//!
//!     cargo run --release --example poisoning_defense
//!
//! Three attacks, three defences:
//! - Boost(50) model poisoning  vs endorsement-time norm-bound
//! - NoiseUpdate model poisoning vs endorsement-time RONI
//! - Lazy clients (update copying) vs PN-sequence detection

use scalesfl::fl::client::{Behavior, TrainConfig};
use scalesfl::sim::{AggDefense, DefenseChoice, Partition, ScaleSfl, SimConfig};

fn base_cfg() -> SimConfig {
    SimConfig {
        shards: 2,
        peers_per_shard: 2,
        clients_per_shard: 4,
        samples_per_client: 80,
        eval_samples: 96,
        test_samples: 512,
        train: TrainConfig { batch: 10, epochs: 2, lr: 0.05, dp: None },
        partition: Partition::Iid,
        verify_aggregate: false,
        seed: 1234,
        ..Default::default()
    }
}

fn main() -> anyhow::Result<()> {
    let Some(ops) = scalesfl::runtime::shared_ops() else {
        anyhow::bail!("artifacts not built — run `make artifacts` first");
    };

    // --- Attack 1: boosted update vs norm-bound --------------------------
    println!("== attack 1: Boost(50) model poisoning, norm-bound defence ==");
    let mut cfg = base_cfg();
    cfg.defense = DefenseChoice::NormBound { max_norm: 8.0 };
    let mut net = ScaleSfl::build(cfg, ops.clone())?;
    net.set_behavior(0, Behavior::Boost(50));
    net.set_behavior(5, Behavior::Boost(50));
    for _ in 0..2 {
        let r = net.run_round()?;
        println!(
            "round {}: accepted {} rejected {} | acc {:.4}",
            r.round, r.accepted_updates, r.rejected_updates, r.global_eval.accuracy
        );
        assert_eq!(r.rejected_updates, 2, "norm-bound must reject both boosters");
    }

    // --- Attack 2: noise updates vs RONI ---------------------------------
    println!("\n== attack 2: NoiseUpdate poisoning, RONI defence ==");
    let mut cfg = base_cfg();
    cfg.defense = DefenseChoice::Roni { max_degradation: 0.05 };
    let mut net = ScaleSfl::build(cfg, ops.clone())?;
    net.set_behavior(1, Behavior::NoiseUpdate);
    // Round 1 establishes a baseline; RONI needs the pinned round-0 model.
    for _ in 0..2 {
        let r = net.run_round()?;
        println!(
            "round {}: accepted {} rejected {} | acc {:.4}",
            r.round, r.accepted_updates, r.rejected_updates, r.global_eval.accuracy
        );
    }

    // --- Attack 3: lazy clients vs PN sequences --------------------------
    println!("\n== attack 3: lazy (copying) client, PN-sequence detection ==");
    let mut cfg = base_cfg();
    cfg.pn_amplitude = 1e-3;
    let mut net = ScaleSfl::build(cfg, ops.clone())?;
    net.set_behavior(2, Behavior::Lazy { victim: 0 });
    let mut total_lazy = 0;
    for _ in 0..2 {
        let r = net.run_round()?;
        total_lazy += r.lazy_detected;
        println!(
            "round {}: lazy detected {} | accepted {} | acc {:.4}",
            r.round, r.lazy_detected, r.accepted_updates, r.global_eval.accuracy
        );
    }
    assert!(total_lazy >= 1, "PN defence must flag the copier at least once");

    // --- Comparison: label-flip Sybils with vs without FoolsGold ---------
    // FoolsGold targets non-IID populations (paper §3.4.6): honest non-IID
    // clients submit diverse updates while Sybils share an objective, so
    // similarity-based re-weighting isolates the Sybil cluster.
    println!("\n== attack 4: 3/8 label-flip Sybils (shared data, non-IID), FoolsGold ==");
    let mut accs = Vec::new();
    for (label, agg) in [("no defence", AggDefense::None), ("foolsgold", AggDefense::FoolsGold)] {
        let mut cfg = base_cfg();
        cfg.partition = Partition::Dirichlet { alpha: 0.3 };
        cfg.agg_defense = agg;
        let mut net = ScaleSfl::build(cfg, ops.clone())?;
        // Sybils: one operator behind three client identities — identical
        // poisoned dataset, so their updates share an objective (the
        // similarity signature FoolsGold keys on).
        let mut poisoned =
            scalesfl::fl::datasets::mnist_like(1234, 0xBAD, 80, ops.input_dim(), 10);
        poisoned.flip_labels();
        for id in [0, 3, 6] {
            net.set_behavior(id, Behavior::LabelFlip);
            net.set_client_data(id, poisoned.clone());
        }
        let mut acc = 0.0;
        for _ in 0..3 {
            acc = net.run_round()?.global_eval.accuracy;
        }
        accs.push(acc);
        println!("{label:<12} final accuracy {acc:.4}");
    }
    assert!(
        accs[1] >= accs[0] - 0.02,
        "foolsgold should not do worse than no defence: {accs:?}"
    );
    println!("\nall defence assertions passed ✔");
    Ok(())
}
