//! Non-IID study (paper §4.2): compare IID, Dirichlet label-skew, and
//! writer-based (FEMNIST-style) partitions on convergence, and report the
//! label-skew statistic for each.
//!
//!     cargo run --release --example noniid_training

use scalesfl::fl::client::TrainConfig;
use scalesfl::fl::{datasets, partition};
use scalesfl::sim::{Partition, ScaleSfl, SimConfig};
use scalesfl::util::prng::Prng;

fn main() -> anyhow::Result<()> {
    let Some(ops) = scalesfl::runtime::shared_ops() else {
        anyhow::bail!("artifacts not built — run `make artifacts` first");
    };

    // Partition skew statistics (no training needed).
    println!("label-skew (mean TV distance to global histogram; 0 = IID):");
    let pool = datasets::mnist_like(7, 8, 4000, ops.input_dim(), 10);
    let mut rng = Prng::new(7);
    let iid = partition::iid(&pool, 8, &mut rng);
    let dir05 = partition::dirichlet(&pool, 8, 0.5, &mut rng);
    let dir01 = partition::dirichlet(&pool, 8, 0.1, &mut rng);
    println!("  iid             {:.4}", partition::label_skew(&iid, 10));
    println!("  dirichlet(0.5)  {:.4}", partition::label_skew(&dir05, 10));
    println!("  dirichlet(0.1)  {:.4}", partition::label_skew(&dir01, 10));

    // Convergence under each partition through the full pipeline.
    let rounds = 4;
    for (label, part) in [
        ("iid", Partition::Iid),
        ("dirichlet(0.5)", Partition::Dirichlet { alpha: 0.5 }),
        ("dirichlet(0.1)", Partition::Dirichlet { alpha: 0.1 }),
        ("writer (femnist)", Partition::Writer),
    ] {
        let cfg = SimConfig {
            shards: 2,
            peers_per_shard: 2,
            clients_per_shard: 4,
            samples_per_client: 80,
            eval_samples: 48,
            test_samples: 512,
            train: TrainConfig { batch: 10, epochs: 2, lr: 0.05, dp: None },
            partition: part,
            verify_aggregate: false,
            seed: 99,
            ..Default::default()
        };
        let mut net = ScaleSfl::build(cfg, ops.clone())?;
        print!("{label:<18}");
        for _ in 0..rounds {
            let r = net.run_round()?;
            print!(" {:.4}", r.global_eval.accuracy);
        }
        println!("   (accuracy per global epoch)");
    }
    println!("\nexpected: IID converges fastest; heavier skew slows convergence");
    Ok(())
}
