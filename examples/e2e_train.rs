//! End-to-end validation driver (DESIGN.md §5): the full three-layer stack
//! on a real small workload.
//!
//!     make artifacts && cargo run --release --example e2e_train
//!
//! Runs sharded federated training through the complete blockchain pipeline
//! for several hundred on-chain-validated local SGD steps, logging the loss
//! curve and the headline metrics (accuracy trajectory + endorsement-count
//! scaling). Results are recorded in EXPERIMENTS.md.
//!
//! Environment knobs: SCALESFL_FULL=1 for the paper-scale run
//! (8 shards x 8 clients, 15 global epochs).

use scalesfl::fl::client::TrainConfig;
use scalesfl::sim::{Partition, ScaleSfl, SimConfig};

fn main() -> anyhow::Result<()> {
    let full = std::env::var("SCALESFL_FULL").map(|v| v == "1").unwrap_or(false);
    let Some(ops) = scalesfl::runtime::shared_ops() else {
        anyhow::bail!("artifacts not built — run `make artifacts` first");
    };
    let (shards, clients, rounds, samples) =
        if full { (8, 8, 15, 100) } else { (4, 4, 6, 80) };
    let train = TrainConfig { batch: 10, epochs: 2, lr: 0.05, dp: None };
    let cfg = SimConfig {
        shards,
        peers_per_shard: 2,
        clients_per_shard: clients,
        samples_per_client: samples,
        eval_samples: 64,
        test_samples: 1024,
        train,
        partition: Partition::Dirichlet { alpha: 0.5 },
        verify_aggregate: true,
        seed: 42,
        ..Default::default()
    };
    let total_clients = shards * clients;
    let steps_per_round = total_clients * train.epochs * (samples / train.batch);
    println!(
        "e2e: {shards} shards x {clients} clients ({} total), non-IID Dirichlet(0.5)",
        total_clients
    );
    println!(
        "model: {} params | {} local SGD steps per global epoch | {} global epochs\n",
        ops.p_pad(),
        steps_per_round,
        rounds
    );
    let started = std::time::Instant::now();
    let mut net = ScaleSfl::build(cfg, ops)?;
    println!(
        "{:<7} {:>12} {:>10} {:>10} {:>12} {:>12}",
        "epoch", "train-loss", "test-acc", "test-loss", "accepted", "cum-steps"
    );
    let mut cum_steps = 0usize;
    for _ in 0..rounds {
        let r = net.run_round()?;
        cum_steps += steps_per_round;
        println!(
            "{:<7} {:>12.4} {:>10.4} {:>10.4} {:>9}/{:<2} {:>12}",
            r.round,
            r.mean_train_loss,
            r.global_eval.accuracy,
            r.global_eval.loss,
            r.accepted_updates,
            r.accepted_updates + r.rejected_updates,
            cum_steps
        );
    }
    let elapsed = started.elapsed().as_secs_f64();
    println!("\ntotal: {cum_steps} on-chain-validated local steps in {elapsed:.1}s");
    println!(
        "endorsement evaluations: {} (C x P_E / S per global epoch x {} epochs)",
        net.eval_invocations, rounds
    );
    for shard in &net.shards {
        shard.peers[0]
            .channel(&shard.channel)
            .unwrap()
            .chain
            .lock()
            .unwrap()
            .verify()
            .expect("shard chain integrity");
    }
    println!("all shard chains + mainchain verified ✔");
    Ok(())
}
