//! Quickstart: spin up a 2-shard ScaleSFL network and run three federated
//! rounds end-to-end through the blockchain.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! What happens (paper §3.4): clients train locally (PJRT-executed SGD),
//! upload weights to the content-addressed store, submit hash+URI metadata
//! transactions; shard committees fetch, hash-verify, and evaluate each
//! update during endorsement; Raft orders endorsed envelopes into blocks;
//! shard aggregates go through the mainchain "catalyst" contract; the
//! finalised global model is pinned back to every shard.

use scalesfl::fl::client::TrainConfig;
use scalesfl::sim::{Partition, ScaleSfl, SimConfig};

fn main() -> anyhow::Result<()> {
    let Some(ops) = scalesfl::runtime::shared_ops() else {
        anyhow::bail!("artifacts not built — run `make artifacts` first");
    };
    let cfg = SimConfig {
        shards: 2,
        peers_per_shard: 2,
        clients_per_shard: 4,
        samples_per_client: 100,
        eval_samples: 64,
        test_samples: 512,
        train: TrainConfig { batch: 10, epochs: 2, lr: 0.05, dp: None },
        partition: Partition::Iid,
        seed: 42,
        ..Default::default()
    };
    println!(
        "building ScaleSFL: {} shards x {} peers, {} clients/shard, model P={} params",
        cfg.shards,
        cfg.peers_per_shard,
        cfg.clients_per_shard,
        ops.p_pad()
    );
    let mut net = ScaleSfl::build(cfg, ops)?;
    let initial = net.ops.evaluate(&net.global, &net.test_set.x, &net.test_set.y)?;
    println!("initial global model: accuracy {:.4}, loss {:.4}\n", initial.accuracy, initial.loss);
    for _ in 0..3 {
        let r = net.run_round()?;
        println!(
            "round {}: accepted {}/{} updates | train loss {:.4} | test acc {:.4}",
            r.round,
            r.accepted_updates,
            r.accepted_updates + r.rejected_updates,
            r.mean_train_loss,
            r.global_eval.accuracy
        );
    }
    // Show what landed on-chain.
    for shard in &net.shards {
        let ch = shard.peers[0].channel(&shard.channel).unwrap();
        println!(
            "\n{}: {} blocks, {} model-update records",
            shard.channel,
            ch.height(),
            ch.scan("models/").len()
        );
        ch.chain.lock().unwrap().verify().expect("chain integrity");
    }
    let main = net.all_peers[0].channel(scalesfl::sim::network::MAINCHAIN).unwrap();
    println!(
        "mainchain: {} blocks, {} shard aggregates, {} finalised globals",
        main.height(),
        main.scan("shards/").len(),
        main.scan("global/").len()
    );
    Ok(())
}
