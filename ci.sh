#!/usr/bin/env sh
# CI gate: formatting, lints (warnings are errors), tier-1 verify, and the
# bench smoke regression gate. `make ci` and .github/workflows/ci.yml both
# run exactly this script, so local and hosted CI cannot drift.
set -eu

echo "==> cargo fmt --check"
cargo fmt --check

echo "==> cargo clippy --all-targets -- -D warnings"
cargo clippy --all-targets -- -D warnings

echo "==> cargo build --release"
cargo build --release

# Examples and benches are the drivers of the submission API; build them
# so API churn can never silently break them again.
echo "==> cargo build --release --examples --benches"
cargo build --release --examples --benches

echo "==> cargo doc --no-deps (warnings denied)"
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

echo "==> cargo test -q"
cargo test -q

# Bench smoke gate: each perf bench runs a fast deterministic --smoke
# configuration (seconds, fixed seeds) into target/smoke/, then
# bench_check fails the build if a headline metric regressed >20% against
# the committed bench-baselines/ or the JSON schema drifted.
echo "==> bench smoke runs (mempool, gateway_pipeline, validation, relay, telemetry, durability, consensus, wire)"
# Stale outputs (e.g. restored from a CI target/ cache, or left by a
# removed bench) must not reach bench_check.
rm -rf target/smoke
cargo bench --bench mempool -- --smoke
cargo bench --bench gateway_pipeline -- --smoke
cargo bench --bench validation -- --smoke
cargo bench --bench relay -- --smoke
cargo bench --bench telemetry -- --smoke
cargo bench --bench durability -- --smoke
cargo bench --bench consensus -- --smoke
cargo bench --bench wire -- --smoke

echo "==> bench_check bench-baselines target/smoke"
cargo run --quiet --release --bin bench_check -- bench-baselines target/smoke

echo "CI OK"
