#!/usr/bin/env sh
# CI gate: formatting, lints (warnings are errors), tier-1 verify.
set -eu

echo "==> cargo fmt --check"
cargo fmt --check

echo "==> cargo clippy --all-targets -- -D warnings"
cargo clippy --all-targets -- -D warnings

echo "==> cargo build --release"
cargo build --release

# Examples and benches are the drivers of the submission API; build them
# so API churn can never silently break them again.
echo "==> cargo build --release --examples --benches"
cargo build --release --examples --benches

echo "==> cargo doc --no-deps (warnings denied)"
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

echo "==> cargo test -q"
cargo test -q

echo "CI OK"
